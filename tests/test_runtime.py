"""Training loop, checkpointing, fault tolerance, elastic meshes, serving,
data pipeline — the 1000-node substrate, exercised at CPU scale."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import HostShardedStream, lm_batch, pose_batch
from repro.runtime.elastic import choose_mesh, surviving_mesh
from repro.runtime.fault import FaultInjector, FaultTolerantRunner
from repro.runtime.serve import BatchingServer, Request
from repro.runtime.train_loop import Trainer, TrainState
from repro.models import transformer as T

from conftest import tiny_dense

MESH1 = MeshConfig((1, 1), ("data", "model"))
SHAPE = ShapeConfig("t", 32, 4, "train")
TC = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=50,
                 checkpoint_every=5, seed=0)


def _trainer(**kw):
    return Trainer(tiny_dense(**kw), SHAPE, MESH1, TC)


def _data(cfg):
    return lambda step: lm_batch(cfg, SHAPE, step)


class TestTrainer:
    def test_loss_decreases(self):
        tr = _trainer()
        state = tr.init_state()
        state, hist = tr.run(state, _data(tr.cfg), 30, log_every=1)
        first = np.mean([h["loss"] for h in hist[:3]])
        last = np.mean([h["loss"] for h in hist[-3:]])
        assert last < first - 0.3, (first, last)

    def test_grad_accum_matches_single_batch(self):
        tr1 = _trainer(grad_accum=1)
        tr2 = _trainer(grad_accum=2)
        s1, s2 = tr1.init_state(), tr2.init_state()
        batch = _data(tr1.cfg)(0)
        s1, m1 = tr1.step_fn(s1, batch)
        s2, m2 = tr2.step_fn(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-3)
        # bf16 microbatch summation flips the sign of near-zero grads, and a
        # first Adam step is +-lr regardless of magnitude — bound by 2.2*lr
        lr_bound = 2.2 * float(TC.learning_rate)
        for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                        jax.tree_util.tree_leaves(s2.params)):
            d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
            assert d.max() <= lr_bound, d.max()
            assert d.mean() < 1e-4


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tr = _trainer()
        state = tr.init_state()
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(7, state, blocking=True)
        like = jax.eval_shape(lambda: state)
        restored, step = mgr.restore(like)
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_keeps_last_k(self, tmp_path):
        tr = _trainer()
        state = tr.init_state()
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, state, blocking=True)
        assert mgr.steps() == [3, 4]

    def test_tmp_dirs_ignored(self, tmp_path):
        os.makedirs(tmp_path / "step_00000009.tmp")
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() is None


class TestFaultTolerance:
    def test_recovery_reproduces_uninterrupted_run(self, tmp_path):
        # uninterrupted reference
        tr_ref = _trainer()
        s_ref = tr_ref.init_state()
        s_ref, h_ref = tr_ref.run(s_ref, _data(tr_ref.cfg), 12, log_every=1)

        # faulty run: injected failures at steps 4 and 9
        tr = _trainer()
        state = tr.init_state()
        mgr = CheckpointManager(str(tmp_path), keep=3)
        runner = FaultTolerantRunner(tr, mgr, max_restarts=5)
        inj = FaultInjector(fail_at_steps={4, 9})
        state, hist = runner.run(state, _data(tr.cfg), 12, on_step=inj)
        assert runner.restarts == 2
        assert int(state.step) == 12
        # per-step history survives the mid-segment faults: contiguous
        # steps 1..12, each metric bit-matching the uninterrupted run
        # (restarted segments replay deterministically)
        assert [h["step"] for h in hist] == list(range(1, 13))
        assert [h["loss"] for h in hist] == [h["loss"] for h in h_ref]
        assert ([h["grad_norm"] for h in hist]
                == [h["grad_norm"] for h in h_ref])
        for a, b in zip(jax.tree_util.tree_leaves(s_ref.params),
                        jax.tree_util.tree_leaves(state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restart_budget_enforced(self, tmp_path):
        tr = _trainer()
        state = tr.init_state()
        mgr = CheckpointManager(str(tmp_path))
        runner = FaultTolerantRunner(tr, mgr, max_restarts=1)
        inj = FaultInjector(fail_at_steps={1, 2, 3, 4, 5})
        with pytest.raises(RuntimeError, match="exceeded"):
            runner.run(state, _data(tr.cfg), 8, on_step=inj)


class TestElastic:
    @given(st.integers(1, 4096))
    @settings(deadline=None)
    def test_choose_mesh_uses_all_possible_devices(self, n):
        mc = choose_mesh(n, prefer_model=16)
        assert mc.num_devices <= n
        assert mc.tp in (1, 2, 4, 8, 16)
        assert mc.num_devices >= n // 2 or mc.num_devices == n  # no huge waste

    def test_surviving_mesh_shrinks(self):
        mc = surviving_mesh(MeshConfig((16, 16), ("data", "model")), 16)
        assert mc.num_devices == 240 or mc.num_devices <= 240
        assert mc.tp <= 16


class TestServe:
    def test_batched_serving_completes_requests(self):
        cfg = tiny_dense()
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        srv = BatchingServer(params, cfg, max_batch=4, prompt_len=8,
                             max_len=24)
        rng = np.random.default_rng(0)
        for i in range(6):
            srv.submit(Request(i, rng.integers(
                0, cfg.vocab_size, rng.integers(2, 8)).astype(np.int32),
                max_new=4))
        done = srv.flush() + srv.flush()
        assert len(done) == 6
        for r in done:
            assert r.output is not None and r.output.shape == (4,)

    def test_bounded_window(self):
        cfg = tiny_dense()
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        srv = BatchingServer(params, cfg, max_batch=2, prompt_len=8,
                             max_len=16)
        for i in range(5):
            srv.submit(Request(i, np.array([1, 2, 3], np.int32), max_new=2))
        assert len(srv.flush()) == 2          # window bounded at max_batch
        assert len(srv.queue) == 3


class TestData:
    def test_lm_batch_deterministic(self):
        cfg = tiny_dense()
        b1 = lm_batch(cfg, SHAPE, 5)
        b2 = lm_batch(cfg, SHAPE, 5)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = lm_batch(cfg, SHAPE, 6)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = tiny_dense()
        b = lm_batch(cfg, SHAPE, 0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_host_sharded_stream_partitions_batch(self):
        cfg = tiny_dense()
        mk = lambda step: lm_batch(cfg, SHAPE, step)
        parts = [HostShardedStream(mk, SHAPE.global_batch, h, 2)(3)
                 for h in range(2)]
        full = mk(3)
        rebuilt = np.concatenate([np.asarray(p["tokens"]) for p in parts])
        np.testing.assert_array_equal(rebuilt, np.asarray(full["tokens"]))

    def test_pose_batch_shapes_and_quat_norm(self):
        b = pose_batch(4, 0)
        assert b["images"].shape == (4, 96, 128, 3)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(b["quat"]), axis=-1), 1.0, atol=1e-5)
