"""Partition-plan invariants + MPAI scheduler Pareto properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.accelerators import PROFILES
from repro.core.cost_model import (LayerCost, layer_costs_from_convspecs,
                                   segment_cost, transformer_layer_costs)
from repro.core.partition import PartitionPlan, Segment
from repro.core.precision import Precision, PrecisionPolicy
from repro.core.qat import baseline_plan, serve_plan, train_plan
from repro.core.scheduler import (best_under_accuracy, mpai_reference_plan,
                                  pareto_frontier, schedule)
from repro.models.cnn import ursonet_table1_layers


# ---------------------------------------------------------------------------
# PartitionPlan
# ---------------------------------------------------------------------------
def test_mpai_plan_covers_and_validates():
    plan = PartitionPlan.mpai(24, split=20)
    plan.validate(24)
    assert plan.segments[0].policy.precision is Precision.INT8
    assert plan.segments[-1].policy.precision is Precision.BF16


def test_plan_rejects_gap():
    bad = PartitionPlan((Segment("a", 0, 4, PrecisionPolicy.bf16()),
                         Segment("b", 6, 8, PrecisionPolicy.bf16())))
    with pytest.raises(ValueError):
        bad.validate(8)


def test_plan_rejects_misaligned_period():
    plan = PartitionPlan.mpai(16, split=3)
    with pytest.raises(ValueError):
        plan.validate(16, period=8)
    plan.align_to_period(8, 16).validate(16, period=8)


@given(st.integers(2, 64), st.integers(1, 63))
@settings(deadline=None)
def test_align_to_period_always_valid(n_layers, split):
    for period in (1, 2, 4, 8):
        if n_layers % period:
            continue
        plan = PartitionPlan.mpai(n_layers, split=min(split, n_layers))
        plan.align_to_period(period, n_layers).validate(n_layers, period)


def test_qat_lifecycle_conversions():
    plan = PartitionPlan.mpai(8, split=6)
    tp = train_plan(plan)
    assert tp.segments[0].policy.mode == "fake"
    sp = serve_plan(tp, use_pallas=True)
    assert sp.segments[0].policy.mode == "quant"
    assert sp.segments[0].policy.use_pallas
    bp = baseline_plan(sp)
    assert all(s.policy.precision is Precision.BF16 for s in bp.segments)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------
LAYER_TABLES = st.lists(
    st.tuples(st.floats(1e6, 1e10), st.floats(1e3, 1e7), st.floats(1e3, 1e6)),
    min_size=2, max_size=12)


def _mk_layers(rows):
    return [LayerCost(f"l{i}", m, w, a, a) for i, (m, w, a) in enumerate(rows)]


@given(LAYER_TABLES)
@settings(deadline=None, max_examples=25)
def test_schedule_returns_nondominated_covering_plans(rows):
    layers = _mk_layers(rows)
    plans = schedule(layers, ["mpsoc_dpu", "myriadx_vpu", "edge_tpu"])
    assert plans
    for p in plans:
        # coverage + contiguity
        assert p.assignments[0][0] == 0
        assert p.assignments[-1][1] == len(layers)
        for (s0, e0, _), (s1, e1, _) in zip(p.assignments, p.assignments[1:]):
            assert e0 == s1
        # non-domination within the returned set
        assert not any(q.dominates(p) for q in plans if q is not p)


def test_pareto_frontier_removes_dominated():
    layers = _mk_layers([(1e9, 1e6, 1e5)] * 4)
    # cortex_a53_fp16 has the same precision (-> same accuracy prior) as the
    # VPU but is strictly slower and hungrier: all-CPU-fp16 must be dominated
    plans = schedule(layers, ["mpsoc_dpu", "myriadx_vpu", "cortex_a53_fp16"])
    names = [tuple(p.assignments) for p in plans]
    assert ((0, 4, "cortex_a53_fp16"),) not in names
    # while the all-fp32-CPU plan legitimately survives on the accuracy axis
    plans2 = schedule(layers, ["myriadx_vpu", "cortex_a53"])
    assert ((0, 4, "cortex_a53"),) in [tuple(p.assignments) for p in plans2]


def test_mpai_reference_is_faster_than_vpu_and_near_dpu_accuracy():
    """Table I's qualitative structure from the cost model."""
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    ref = mpai_reference_plan(layers)
    vpu = schedule(layers, ["myriadx_vpu"], max_segments=1)[0]
    dpu = schedule(layers, ["mpsoc_dpu"], max_segments=1)[0]
    assert ref.latency_s < vpu.latency_s          # MPAI beats full-VPU
    assert ref.latency_s > dpu.latency_s          # but DPU-only is fastest
    assert ref.accuracy_penalty < dpu.accuracy_penalty  # ...and less accurate


def test_best_under_accuracy_constraint():
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    plans = schedule(layers, ["mpsoc_dpu", "myriadx_vpu"],
                     accuracy_penalty={"mpsoc_dpu": 0.3})
    tight = best_under_accuracy(plans, 0.05)
    loose = best_under_accuracy(plans, 1.0)
    assert tight is not None and loose is not None
    assert loose.latency_s <= tight.latency_s
    assert tight.accuracy_penalty <= 0.05


def test_cost_model_monotone_in_flops():
    prof = PROFILES["tpu_v5e_bf16"]
    small = segment_cost(_mk_layers([(1e9, 1e6, 1e5)]), prof)
    big = segment_cost(_mk_layers([(1e12, 1e6, 1e5)]), prof)
    assert big.compute_s > small.compute_s


def test_transformer_layer_costs_cover_all_archs():
    from repro.configs import ARCH_NAMES, get_config
    for name in ARCH_NAMES:
        cfg = get_config(name)
        layers = transformer_layer_costs(cfg, 4096)
        assert len(layers) == cfg.num_layers
        assert all(l.macs > 0 for l in layers)
