"""core.pipeline.pipeline_apply: microbatch drain correctness against a
sequential per-microbatch reference — the stage-handoff seam the
co-processing serving split rides.  Covers the GPipe-style schedule's
edges: n_micro < num_stages (the drain outlasts the feed) and the
single-microbatch case, where every step past warm-up hits the
``out_idx`` clip.  Runs in subprocesses with 8 faked host devices, same
pattern as test_distributed (the main test process stays at 1 device).
"""
import os
import subprocess
import sys
import textwrap

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src"}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_BODY = """
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.pipeline import pipeline_apply

    def check(num_stages, n_micro):
        d, b = 8, 2
        mesh = Mesh(np.array(jax.devices()[:num_stages]), ("stage",))
        ws = jax.random.normal(jax.random.PRNGKey(0),
                               (num_stages, d, d)) * 0.3

        def mk(s):
            # distinctive per-stage math so any feed/drain misalignment
            # (wrong microbatch, wrong stage order) shows in the output
            def fn(x, params):
                h = jnp.tanh(x @ params) + (s + 1)
                return h, h * (s + 1)
            return fn
        fns = [mk(s) for s in range(num_stages)]
        xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, b, d))
        outs = pipeline_apply(mesh, "stage", fns, ws, xs,
                              hidden_shape=(b, d), out_shape=(b, d),
                              hidden_dtype=jnp.float32,
                              out_dtype=jnp.float32)
        assert outs.shape == (n_micro, b, d), outs.shape
        for m in range(n_micro):          # sequential reference
            x = xs[m].astype(jnp.float32)
            for s in range(num_stages):
                x, out = fns[s](x, ws[s])
            err = float(jnp.max(jnp.abs(outs[m] - out)))
            assert err < 1e-5, (num_stages, n_micro, m, err)
        print("ok", num_stages, n_micro)
"""


def test_pipeline_drain_matches_sequential_reference():
    """More microbatches than stages: the steady-state schedule."""
    out = _run(_BODY + """
    check(2, 5)
    check(4, 6)
    """)
    assert out.count("ok") == 2


def test_pipeline_fewer_microbatches_than_stages():
    """n_micro < num_stages: the pipeline never fills; every microbatch
    is still delivered once, despite the feed index clipping."""
    out = _run(_BODY + """
    check(4, 2)
    check(8, 3)
    """)
    assert out.count("ok") == 2


def test_pipeline_single_microbatch_out_idx_clip_edge():
    """n_micro == 1: out_idx clips to 0 for every drain step; the final
    write (the only take=True step) must not be clobbered by the
    clipped no-op writes after it."""
    out = _run(_BODY + """
    check(2, 1)
    check(4, 1)
    """)
    assert out.count("ok") == 2
