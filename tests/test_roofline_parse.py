"""Roofline machinery: HLO collective parsing + model-flops accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.roofline import (RooflineReport, _shape_bytes, model_flops,
                            parse_collectives)

HLO = """
HloModule test
fused_computation {
  ...
}
ENTRY main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %p1 = bf16[8,256]{1,0} parameter(1)
  %ar = f32[16,1024]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  %ag = bf16[16,256]{1,0} all-gather(%p1), dimensions={0}
  %cp = f32[16,1024]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  %ars = f32[16,1024]{1,0} all-reduce-start(%p0), replica_groups={}
  %ard = f32[16,1024]{1,0} all-reduce-done(%ars)
  ROOT %t = (f32[16,1024]{1,0}) tuple(%cp)
}
"""


def test_parse_collectives_sums_operand_bytes():
    st = parse_collectives(HLO)
    # all-reduce: 16*1024*4 = 65536; plus the async start pair counted once
    assert st.bytes_by_kind["all-reduce"] == 65536 * 2
    assert st.count_by_kind["all-reduce"] == 2
    # all-gather operand: 8*256*2 = 4096
    assert st.bytes_by_kind["all-gather"] == 4096
    # collective-permute operand = the all-reduce result (65536)
    assert st.bytes_by_kind["collective-permute"] == 65536
    # -done must not double count
    assert st.total_count == 4


def test_shape_bytes_tuple_types():
    assert _shape_bytes("(f32[2,2]{1,0}, s8[4]{0})") == 16 + 4
    assert _shape_bytes("bf16[128]{0}") == 256
    assert _shape_bytes("pred[]") == 1


def test_model_flops_moe_uses_active_params():
    dense = get_config("qwen3-14b")
    moe = get_config("olmoe-1b-7b")
    sh = SHAPES["train_4k"]
    assert model_flops(dense, sh) == 6.0 * dense.param_count() * sh.tokens
    assert model_flops(moe, sh) < 6.0 * moe.param_count() * sh.tokens
    assert model_flops(moe, sh) == 6.0 * moe.active_param_count() * sh.tokens


def test_roofline_report_terms_and_dominant():
    rep = RooflineReport(arch="a", shape="s", mesh=(16, 16), chips=256,
                         hlo_flops=197e12 * 0.1,      # 100 ms compute
                         hlo_bytes=819e9 * 0.05,      # 50 ms memory
                         collective_bytes=50e9 * 0.2,  # 200 ms collective
                         model_flops=1e15)
    assert abs(rep.compute_s - 0.1) < 1e-9
    assert abs(rep.memory_s - 0.05) < 1e-9
    assert abs(rep.collective_s - 0.2) < 1e-9
    assert rep.dominant == "collective"
    assert rep.step_s == rep.collective_s
    r = rep.row()
    assert r["dominant"] == "collective"


def test_active_params_all_archs_positive_and_leq_total():
    from repro.configs import ARCH_NAMES
    for name in ARCH_NAMES:
        cfg = get_config(name)
        assert 0 < cfg.active_param_count() <= cfg.param_count(), name
