"""The shared Poisson open-loop driver (serving/traffic.py): seeded
determinism, arrival-rate calibration, class-mix weights, payload RNG
ordering, and end-to-end trace reproducibility through a fleet."""
import numpy as np
import pytest

from repro.launch.route import vision_fleet_spec
from repro.router import SLO_CLASSES
from repro.serving.traffic import open_loop, poisson_arrivals

MIX_CLASSES = ["downlink-critical", "bulk-reprocess"]
MIX_WEIGHTS = [0.7, 0.3]


def test_poisson_arrivals_seeded_determinism():
    a = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                         n_requests=200, seed=7)
    b = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                         n_requests=200, seed=7)
    assert a == b                              # bit-identical trace
    c = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                         n_requests=200, seed=8)
    assert a != c                              # seed actually matters


def test_poisson_arrivals_rate_and_monotonicity():
    rate = 50.0
    trace = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=rate,
                             n_requests=4000, seed=0)
    times = [t for t, _, _ in trace]
    assert all(b > a for a, b in zip(times, times[1:]))
    gaps = np.diff([0.0] + times)
    # 4000 exponential draws: the mean gap sits within a few percent of
    # 1/rate (deterministic for the fixed seed; 5% is generous)
    assert np.mean(gaps) == pytest.approx(1.0 / rate, rel=0.05)


def test_poisson_arrivals_class_mix_follows_weights():
    trace = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=100.0,
                             n_requests=4000, seed=1)
    frac = sum(slo == "downlink-critical" for _, slo, _ in trace) / 4000
    assert frac == pytest.approx(0.7, abs=0.03)


def test_poisson_payload_fn_draws_are_reproducible():
    def payload(rng):
        return rng.integers(0, 256, 4).astype(np.int32)

    a = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                         n_requests=32, seed=3, payload_fn=payload)
    b = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                         n_requests=32, seed=3, payload_fn=payload)
    for (ta, _, pa), (tb, _, pb) in zip(a, b):
        assert ta == tb
        np.testing.assert_array_equal(pa, pb)
    # payload draws consume the shared RNG: omitting them changes the
    # subsequent arrival times (single-stream contract the pre-facade
    # launch/route.py established)
    plain = poisson_arrivals(MIX_CLASSES, MIX_WEIGHTS, rate_hz=50.0,
                             n_requests=32, seed=3)
    assert [t for t, _, _ in a][1:] != [t for t, _, _ in plain][1:]


def test_open_loop_trace_reproducible_end_to_end():
    """Two identically-seeded runs through identical fleets produce the
    same admissions, completions, and per-request latencies on the
    virtual clock."""
    def run():
        client = vision_fleet_spec().build()
        classes = [SLO_CLASSES[n] for n in MIX_CLASSES]
        handles = open_loop(client, classes, MIX_WEIGHTS, rate_hz=100.0,
                            n_requests=40, seed=5)
        snap = client.telemetry
        lats = [h.telemetry["latency_s"] for h in handles]
        return snap, lats, client.outstanding

    snap_a, lats_a, out_a = run()
    snap_b, lats_b, out_b = run()
    assert out_a == out_b == 0                 # both drained
    assert lats_a == lats_b
    for key in ("admitted", "rejected", "completed", "violations",
                "dropped"):
        assert snap_a[key] == snap_b[key]
    assert snap_a["admitted"] == 40 - snap_a["rejected"]
    assert snap_a["completed"] + snap_a["dropped"] == snap_a["admitted"]


def test_open_loop_returns_rejected_handles_too():
    from repro.router import SLOClass
    client = vision_fleet_spec().build()
    impossible = SLOClass("impossible", max_latency_s=1e-9)
    handles = open_loop(client, [impossible], [1.0], rate_hz=100.0,
                        n_requests=5, seed=0)
    assert len(handles) == 5
    assert all(h.done and not h.admitted for h in handles)
    assert client.telemetry["rejected"] == 5


def test_open_loop_marks_truncated_runs_and_warns():
    """The max_s safety net used to break out silently with arrivals
    never submitted and handles incomplete — traces shrank without a
    trace.  Now the returned TrafficTrace flags it and a warning fires."""
    from repro.serving.traffic import TrafficTrace

    client = vision_fleet_spec().build()
    classes = [SLO_CLASSES[n] for n in MIX_CLASSES]
    # arrivals spread over ~10s of virtual time, hard-capped at 0.1s:
    # most of the trace can never be submitted
    with pytest.warns(RuntimeWarning, match="open_loop truncated"):
        trace = open_loop(client, classes, MIX_WEIGHTS, rate_hz=5.0,
                          n_requests=50, seed=0, max_s=0.1)
    assert isinstance(trace, TrafficTrace)
    assert trace.truncated
    assert trace.unsubmitted > 0
    assert len(trace) + trace.unsubmitted == 50
    assert trace.incomplete == sum(1 for h in trace if not h.done)


def test_open_loop_full_run_is_not_truncated():
    client = vision_fleet_spec().build()
    classes = [SLO_CLASSES[n] for n in MIX_CLASSES]
    trace = open_loop(client, classes, MIX_WEIGHTS, rate_hz=200.0,
                      n_requests=20, seed=0)
    assert not trace.truncated
    assert trace.unsubmitted == 0 and trace.incomplete == 0
    assert len(trace) == 20
