"""End-to-end system behaviour: the full MPAI lifecycle on one tiny LM —
schedule a partition, QAT-train it, deploy int8, serve with a KV cache —
exercising every layer of the framework in one flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.core import qat
from repro.core.cost_model import transformer_layer_costs
from repro.core.scheduler import best_under_accuracy, schedule
from repro.data.pipeline import lm_batch
from repro.models import transformer as T
from repro.runtime.serve import BatchingServer, Request
from repro.runtime.train_loop import Trainer

CFG = ModelConfig(name="sys", family="dense", num_layers=4, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  remat=False)
SHAPE = ShapeConfig("t", 32, 8, "train")


def test_full_mpai_lifecycle():
    # 1. scheduler picks the partition from the cost model
    layers = transformer_layer_costs(CFG, SHAPE.seq_len)
    plans = schedule(layers, ["tpu_v5e_int8", "tpu_v5e_bf16"],
                     accuracy_penalty={"tpu_v5e_int8": 0.05})
    chosen = best_under_accuracy(plans, max_penalty=0.045)
    assert chosen is not None
    plan = chosen.to_partition_plan(qat=True)
    assert any(s.policy.precision.value == "int8" for s in plan.segments)

    # 2. partition-aware training
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=40)
    tr = Trainer(CFG, SHAPE, MeshConfig((1, 1), ("data", "model")), tc,
                 plan=qat.train_plan(plan))
    state = tr.init_state()
    state, hist = tr.run(state, lambda s: lm_batch(CFG, SHAPE, s), 40,
                         log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2

    # 3. int8 deployment evaluates close to the QAT-trained bf16 eval
    b = lm_batch(CFG, SHAPE, 999)
    serve = qat.serve_plan(plan)
    l_bf16 = float(T.loss_fn(state.params, CFG, b["tokens"], b["labels"]))
    l_int8 = float(T.loss_fn(state.params, CFG, b["tokens"], b["labels"],
                             plan=serve))
    assert abs(l_int8 - l_bf16) < 0.5, (l_bf16, l_int8)

    # 4. batched serving with the deployed plan completes requests
    srv = BatchingServer(state.params, CFG, plan=serve, max_batch=4,
                         prompt_len=8, max_len=16)
    rng = np.random.default_rng(0)
    for i in range(4):
        srv.submit(Request(i, rng.integers(0, 256, 5).astype(np.int32),
                           max_new=4))
    done = srv.flush()
    assert len(done) == 4 and all(r.output.shape == (4,) for r in done)
