"""Shared fixtures.  NOTE: no XLA_FLAGS device faking here — tests run on
the real single CPU device; multi-device behaviour is exercised by
subprocess tests (tests/test_distributed.py) so the device count stays 1
for everything else.

If ``hypothesis`` is not installed, a stub is registered so the four
property-test modules still *import* (their non-property tests run; the
``@given`` tests are skipped).  Without this, collection of the whole
suite aborts on the first ImportError.  Install the real thing with
``pip install -r requirements-dev.txt``.
"""
import sys
import types

import jax
import pytest

try:                                   # pragma: no cover - env dependent
    import hypothesis  # noqa: F401
except ImportError:                    # build a collection-safe stub
    class _Strategy:
        """Placeholder for any strategy object: every attribute access,
        call, or combinator returns another placeholder."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _st = _Strategy()

    def _given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def _settings(*a, **k):
        return lambda fn: fn

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.strategies = _st
    extra = types.ModuleType("hypothesis.extra")
    extra.numpy = _st
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = _st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = _st


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_dense(**kw):
    from repro.configs.base import ModelConfig
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                remat=False)
    base.update(kw)
    return ModelConfig(**base)
