"""Shared fixtures.  NOTE: no XLA_FLAGS device faking here — tests run on
the real single CPU device; multi-device behaviour is exercised by
subprocess tests (tests/test_distributed.py) so the device count stays 1
for everything else."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_dense(**kw):
    from repro.configs.base import ModelConfig
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                remat=False)
    base.update(kw)
    return ModelConfig(**base)
