"""Continuous-batching engine: per-request outputs must be identical to
solo windowed flush() runs regardless of admission order; exact max_new
accounting; OutOfBlocks deferral; attention-only guard; per-request
sampling determinism; the deprecated BatchingServer shim."""
import numpy as np
import jax
import pytest

from repro.models import transformer as T
from repro.runtime.paging import OutOfBlocksError
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import (BatchingServer, ContinuousBatchingEngine,
                                 Request, WindowedBaselineServer)

from conftest import tiny_dense

PROMPT_LEN, MAX_LEN, BLOCK = 8, 16, 4


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(3)
    return [(i,
             rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN))
                          ).astype(np.int32),
             int(rng.integers(1, MAX_LEN - PROMPT_LEN + 1)))
            for i in range(6)]


@pytest.fixture(scope="module")
def solo_reference(model, workload):
    """Each request served alone by the windowed baseline."""
    cfg, params = model
    ref = {}
    for rid, prompt, max_new in workload:
        srv = WindowedBaselineServer(params, cfg, max_batch=1,
                                     prompt_len=PROMPT_LEN, max_len=MAX_LEN)
        srv.submit(Request(rid, prompt, max_new=max_new))
        srv.flush()
        ref[rid] = srv.done[rid].output
    return ref


def _drain(engine):
    done = []
    steps = 0
    while engine.pending:
        done += engine.step()
        steps += 1
        assert steps < 1000, "engine failed to make progress"
    return done


# admission orders: arrival, reversed, and an interleave — outputs must
# not depend on which requests shared a decode batch
@pytest.mark.parametrize("order", [
    [0, 1, 2, 3, 4, 5], [5, 4, 3, 2, 1, 0], [3, 0, 5, 1, 4, 2]])
def test_outputs_match_solo_flush_any_admission_order(model, workload,
                                                      solo_reference, order):
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=3,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    for idx in order:
        rid, prompt, max_new = workload[idx]
        eng.submit(Request(rid, prompt, max_new=max_new))
    done = _drain(eng)
    assert len(done) == len(workload)
    for rid, prompt, max_new in workload:
        out = eng.done[rid].output
        assert out.shape == (max_new,)          # max_new honored exactly
        np.testing.assert_array_equal(out, solo_reference[rid])


def test_mid_decode_admission_and_exact_steps(model):
    """A long request decodes while short ones churn through the freed
    slots; total decode steps stay well under the windowed equivalent."""
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    prompts = np.random.default_rng(0).integers(
        0, 256, (5, 4)).astype(np.int32)
    eng.submit(Request(0, prompts[0], max_new=8))
    for i in range(1, 5):
        eng.submit(Request(i, prompts[i], max_new=2))
    _drain(eng)
    assert len(eng.done) == 5
    for i in range(1, 5):
        assert eng.done[i].output.shape == (2,)
    # windowed max_batch=2 would burn >= 3 windows x max(max_new) steps;
    # continuous: the rid-0 slot runs 8 steps total while the other slot
    # serves all four short requests
    assert eng.stats()["decode_steps"] <= 9
    assert eng.stats()["total_tokens"] == 8 + 4 * 2


def test_admission_defers_on_block_exhaustion(model):
    """Pool sized for one max-length request: admission falls back to
    one-at-a-time instead of crashing, and everything completes."""
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=3,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK,
                                   num_blocks=MAX_LEN // BLOCK)
    for i in range(3):
        eng.submit(Request(i, np.array([1, 2, 3], np.int32), max_new=4))
    assert eng.occupancy == 0.0
    done = _drain(eng)
    assert len(done) == 3
    for r in done:
        assert r.output.shape == (4,)


def test_single_token_requests_complete_at_admission(model):
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    eng.submit(Request(0, np.array([5], np.int32), max_new=1))
    # max_new=0 completes with empty output, like the windowed baseline
    eng.submit(Request(1, np.array([5], np.int32), max_new=0))
    done = eng.step()
    assert sorted(r.rid for r in done) == [0, 1]
    assert eng.done[0].output.shape == (1,)
    assert eng.done[1].output.shape == (0,)
    assert eng.alloc.available == eng.alloc.num_blocks   # all blocks freed


def test_blocks_recycle_across_requests(model, workload):
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    for rid, prompt, max_new in workload:
        eng.submit(Request(rid, prompt, max_new=max_new))
    _drain(eng)
    assert eng.alloc.available == eng.alloc.num_blocks
    assert (eng.table == -1).all()


def test_paged_decode_rejects_non_attention_stacks(model):
    cfg, params = model
    hybrid = tiny_dense(mixer="mamba")
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousBatchingEngine(params, hybrid, max_slots=2,
                                 prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                 block_size=BLOCK)


def test_out_of_blocks_is_typed_and_atomic():
    from repro.runtime.paging import BlockAllocator, plan_blocks
    alloc = BlockAllocator(4)
    table = -np.ones((2, 8), np.int32)
    with pytest.raises(OutOfBlocksError):
        plan_blocks(table, alloc, [3, 3])
    assert alloc.available == 4            # nothing leaked
    assert (table == -1).all()             # caller's table untouched


# ---------------------------------------------------------------------------
# per-request sampling
# ---------------------------------------------------------------------------
def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    while engine.pending:
        engine.step()
    return engine.done


def test_sampling_deterministic_and_batch_invariant(model, workload):
    """Same seed -> same tokens, whether the request decodes solo or
    packed into slots with strangers (keys fold (seed, token index),
    never batch position or composition)."""
    cfg, params = model
    sp = SamplingParams(temperature=1.0, top_k=8, seed=42)
    _, prompt, _ = workload[0]

    def engine():
        return ContinuousBatchingEngine(params, cfg, max_slots=3,
                                        prompt_len=PROMPT_LEN,
                                        max_len=MAX_LEN, block_size=BLOCK)
    solo = _run(engine(), [Request(0, prompt, max_new=6, sampling=sp)])
    again = _run(engine(), [Request(0, prompt, max_new=6, sampling=sp)])
    np.testing.assert_array_equal(solo[0].output, again[0].output)
    mixed = _run(engine(), [
        Request(7, workload[1][1], max_new=3),
        Request(0, prompt, max_new=6, sampling=sp),
        Request(8, workload[2][1], max_new=2),
    ])
    np.testing.assert_array_equal(mixed[0].output, solo[0].output)


def test_sampled_requests_do_not_perturb_greedy_neighbors(
        model, workload, solo_reference):
    """Greedy requests sharing a batch with a sampled one still match
    their solo-greedy goldens (sampling defaults keep goldens intact)."""
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=3,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    sp = SamplingParams(temperature=0.9, seed=3)
    reqs = [Request(workload[0][0], workload[0][1],
                    max_new=workload[0][2]),
            Request(99, workload[1][1], max_new=6, sampling=sp),
            Request(workload[2][0], workload[2][1],
                    max_new=workload[2][2])]
    done = _run(eng, reqs)
    for rid, _, max_new in (workload[0], workload[2]):
        np.testing.assert_array_equal(done[rid].output,
                                      solo_reference[rid])
    assert done[99].output.shape == (6,)


def test_sampling_respects_top_k_one(model, workload):
    """top_k=1 at any temperature is argmax — must equal the greedy run."""
    cfg, params = model
    rid, prompt, _ = workload[3]

    def engine():
        return ContinuousBatchingEngine(params, cfg, max_slots=2,
                                        prompt_len=PROMPT_LEN,
                                        max_len=MAX_LEN, block_size=BLOCK)
    greedy = _run(engine(), [Request(0, prompt, max_new=5)])
    topk1 = _run(engine(), [Request(0, prompt, max_new=5,
                                    sampling=SamplingParams(
                                        temperature=2.0, top_k=1,
                                        seed=11))])
    np.testing.assert_array_equal(topk1[0].output, greedy[0].output)


# ---------------------------------------------------------------------------
# deprecated windowed entry point
# ---------------------------------------------------------------------------
def test_batching_server_shim_warns_and_forwards_to_engine(model):
    cfg, params = model
    with pytest.warns(DeprecationWarning, match="repro.serving"):
        srv = BatchingServer(params, cfg, max_batch=2,
                             prompt_len=PROMPT_LEN, max_len=MAX_LEN)
    assert isinstance(srv, ContinuousBatchingEngine)
    srv.submit(Request(0, np.array([1, 2, 3], np.int32), max_new=3))
    srv.flush()
    assert srv.done[0].output.shape == (3,)


def test_batching_server_shim_falls_back_for_non_pageable_stacks():
    hybrid = tiny_dense(mixer="mamba")
    params = T.model_init(jax.random.PRNGKey(0), hybrid)
    with pytest.warns(DeprecationWarning):
        srv = BatchingServer(params, hybrid, max_batch=2,
                             prompt_len=PROMPT_LEN, max_len=MAX_LEN)
    assert isinstance(srv, WindowedBaselineServer)


# ---------------------------------------------------------------------------
# accounting bugfixes
# ---------------------------------------------------------------------------
def test_max_new_zero_emits_no_tokens_and_counts_none(model):
    """Regression: the admission token used to be counted into
    total_tokens even for max_new=0 requests, whose token is never
    emitted — inflating tokens/s."""
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    emitted = []
    eng.on_token = lambda rid, tok: emitted.append((rid, tok))
    eng.submit(Request(0, np.array([5, 6], np.int32), max_new=0))
    done = eng.step()
    assert [r.rid for r in done] == [0]
    assert eng.done[0].output.shape == (0,)
    assert eng.stats()["total_tokens"] == 0
    assert emitted == []                 # counted tokens == emitted tokens
    # a real request afterwards counts exactly its own tokens
    eng.submit(Request(1, np.array([5, 6], np.int32), max_new=3))
    _drain(eng)
    assert eng.stats()["total_tokens"] == 3
    assert [rid for rid, _ in emitted] == [1, 1, 1]


def test_empty_prompt_rejected_at_submit(model):
    cfg, params = model
    eng = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                                   block_size=BLOCK)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(0, np.zeros((0,), np.int32), max_new=2))
    srv = WindowedBaselineServer(params, cfg, max_batch=2,
                                 prompt_len=PROMPT_LEN, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.submit(Request(0, np.zeros((0,), np.int32), max_new=2))


# ---------------------------------------------------------------------------
# chunked paged prefill (prompts beyond the prompt_len bucket)
# ---------------------------------------------------------------------------
LONG_MAX_LEN = 48


def _long_engine(model, **kw):
    cfg, params = model
    kw.setdefault("max_slots", 3)
    kw.setdefault("prompt_len", PROMPT_LEN)
    kw.setdefault("max_len", LONG_MAX_LEN)
    kw.setdefault("block_size", BLOCK)
    return ContinuousBatchingEngine(params, cfg, **kw)


def test_chunked_prefill_matches_windowed_at_full_length(model):
    """A prompt longer than the prompt_len bucket admits via chunked
    paged prefill and decodes to exactly the tokens of a windowed
    baseline whose bucket spans the whole (padded) prompt."""
    cfg, params = model
    prompt = np.random.default_rng(11).integers(0, 256, 21).astype(np.int32)
    eng = _long_engine(model)
    eng.submit(Request(0, prompt, max_new=6))
    _drain(eng)
    padded = eng.padded_prompt_len(21)          # 24 for chunk 8
    ref = WindowedBaselineServer(params, cfg, max_batch=1,
                                 prompt_len=padded, max_len=padded + 8)
    ref.submit(Request(0, prompt, max_new=6))
    ref.flush()
    np.testing.assert_array_equal(eng.done[0].output, ref.done[0].output)
    assert eng.alloc.available == eng.alloc.num_blocks


def test_chunked_prefill_mixed_with_bucket_admissions(model):
    """Long and short prompts share slots and decode batches; outputs
    match their solo runs and every block recycles."""
    cfg, params = model
    rng = np.random.default_rng(4)
    reqs = [(0, rng.integers(0, 256, 30).astype(np.int32), 5),
            (1, rng.integers(0, 256, 4).astype(np.int32), 3),
            (2, rng.integers(0, 256, 17).astype(np.int32), 7)]
    eng = _long_engine(model)
    for rid, p, mn in reqs:
        eng.submit(Request(rid, p, max_new=mn))
    _drain(eng)
    for rid, p, mn in reqs:
        solo = _long_engine(model)
        solo.submit(Request(rid, p, max_new=mn))
        _drain(solo)
        np.testing.assert_array_equal(eng.done[rid].output,
                                      solo.done[rid].output)
    assert eng.alloc.available == eng.alloc.num_blocks
    assert (eng.table == -1).all()


def test_chunked_prefill_defers_on_block_exhaustion(model):
    """A pool sized for one long request serves them one at a time."""
    cfg, params = model
    prompt = np.random.default_rng(5).integers(0, 256, 20).astype(np.int32)
    eng = _long_engine(model, max_slots=3,
                       num_blocks=LONG_MAX_LEN // BLOCK)
    for i in range(3):
        eng.submit(Request(i, prompt, max_new=4))
    done = _drain(eng)
    assert len(done) == 3
    assert eng.stats()["deferrals"] > 0
    assert eng.alloc.available == eng.alloc.num_blocks


def test_prompt_over_max_len_rejected(model):
    eng = _long_engine(model)
    with pytest.raises(AssertionError):
        eng.submit(Request(0, np.arange(LONG_MAX_LEN + 1, dtype=np.int32),
                           max_new=4))


# ---------------------------------------------------------------------------
# prefix-block sharing (content-hashed index)
# ---------------------------------------------------------------------------
def test_prefix_sharing_reuses_live_blocks_without_changing_outputs(model):
    cfg, params = model
    prompt = np.random.default_rng(6).integers(0, 256, 24).astype(np.int32)
    eng = _long_engine(model)
    eng.submit(Request(0, prompt, max_new=6))
    eng.submit(Request(1, prompt, max_new=6))
    eng.step()                       # both admitted into one round
    st = eng.stats()
    # the second request's chunks[:-1] (16 tokens = 4 blocks) came from
    # the index, and only its final chunk was recomputed
    assert st["shared_block_hits"] == 16 // BLOCK
    assert st["prefill_tokens"] == 24 + 8
    _drain(eng)
    np.testing.assert_array_equal(eng.done[0].output, eng.done[1].output)
    solo = _long_engine(model)
    solo.submit(Request(9, prompt, max_new=6))
    _drain(solo)
    np.testing.assert_array_equal(eng.done[0].output, solo.done[9].output)
    # refcounts drained: every block is back in the pool and the index
    # holds nothing once the last sharer finished
    assert eng.alloc.available == eng.alloc.num_blocks
    assert eng.shared.release([]) == []


def test_prefix_sharing_survives_first_owner_finishing_last(model):
    """The owner releasing before the sharer must not free shared
    blocks under the still-running request (refcount, not ownership)."""
    cfg, params = model
    prompt = np.random.default_rng(8).integers(0, 256, 24).astype(np.int32)
    eng = _long_engine(model, max_slots=2)
    eng.submit(Request(0, prompt, max_new=1))    # owner: done at admission
    eng.submit(Request(1, prompt, max_new=8))    # sharer decodes on
    _drain(eng)
    solo = _long_engine(model, max_slots=2)
    solo.submit(Request(1, prompt, max_new=8))
    _drain(solo)
    np.testing.assert_array_equal(eng.done[1].output, solo.done[1].output)
    assert eng.alloc.available == eng.alloc.num_blocks


# ---------------------------------------------------------------------------
# co-processing handoff (prefill-class -> decode-class engines)
# ---------------------------------------------------------------------------
def _coproc(model, prefill_chunk=None, decode_blocks=None):
    from repro.runtime.serve import CoProcServer
    cfg, params = model
    pre = ContinuousBatchingEngine(params, cfg, max_slots=1,
                                   prompt_len=PROMPT_LEN,
                                   max_len=LONG_MAX_LEN, block_size=BLOCK,
                                   prefill_chunk=prefill_chunk)
    dec = ContinuousBatchingEngine(params, cfg, max_slots=2,
                                   prompt_len=PROMPT_LEN,
                                   max_len=LONG_MAX_LEN, block_size=BLOCK,
                                   num_blocks=decode_blocks)
    return CoProcServer(pre, dec)


def test_coproc_outputs_match_unified_engine(model):
    rng = np.random.default_rng(12)
    reqs = [(0, rng.integers(0, 256, 20).astype(np.int32), 6),
            (1, rng.integers(0, 256, 5).astype(np.int32), 3),
            (2, rng.integers(0, 256, 33).astype(np.int32), 4)]
    co = _coproc(model)
    uni = _long_engine(model)
    for srv in (co, uni):
        for rid, p, mn in reqs:
            srv.submit(Request(rid, p, max_new=mn))
        while srv.pending:
            srv.step()
    for rid, _, mn in reqs:
        assert co.done[rid].output.shape == (mn,)
        np.testing.assert_array_equal(co.done[rid].output,
                                      uni.done[rid].output)
    assert co.stats()["handoffs"] == 3


def test_coproc_streams_every_token_exactly_once(model):
    """Exact stream completeness across the handoff: the prefill stage
    emits token 0, the decode stage the rest — no loss, no dup."""
    rng = np.random.default_rng(13)
    co = _coproc(model)
    emitted = []
    co.on_token = lambda rid, tok: emitted.append((rid, tok))
    reqs = [(i, rng.integers(0, 256, int(rng.integers(10, 30))
                             ).astype(np.int32), int(rng.integers(1, 7)))
            for i in range(4)]
    for rid, p, mn in reqs:
        co.submit(Request(rid, p, max_new=mn))
    while co.pending:
        co.step()
    for rid, _, mn in reqs:
        stream = [t for r, t in emitted if r == rid]
        assert len(stream) == mn
        np.testing.assert_array_equal(stream, co.done[rid].output)


def test_coproc_wide_prefill_chunk_matches_narrow(model):
    """The DPU-analogue's wide fused chunk is a pure scheduling choice:
    per-block online softmax makes the math identical at any width."""
    prompt = np.random.default_rng(14).integers(0, 256, 37).astype(np.int32)
    wide = _coproc(model, prefill_chunk=40)
    narrow = _coproc(model)
    outs = {}
    for name, srv in (("wide", wide), ("narrow", narrow)):
        srv.submit(Request(0, prompt, max_new=5))
        while srv.pending:
            srv.step()
        outs[name] = srv.done[0].output
    # both pad to 40 tokens here (ceil(37/8)*8 == ceil(37/40)*40), so
    # the streams must agree token for token
    np.testing.assert_array_equal(outs["wide"], outs["narrow"])


def test_coproc_seam_backpressure_defers_without_losing_work(model):
    """A decode pool sized for one request at a time parks the prefilled
    handoff at the seam instead of dropping or re-prefilling it."""
    rng = np.random.default_rng(15)
    co = _coproc(model, decode_blocks=LONG_MAX_LEN // BLOCK)
    for i in range(3):
        co.submit(Request(i, rng.integers(0, 256, 20).astype(np.int32),
                          max_new=4))
    pre_tokens_seen = set()
    while co.pending:
        co.step()
        pre_tokens_seen.add(co.prefill.prefill_tokens)
    assert len(co.done) == 3
    assert co.stats()["deferrals"] > 0
    # prefill ran exactly once per request (24 padded tokens each):
    # deferral parks the handoff, it never burns the prefill again
    assert co.prefill.prefill_tokens == 3 * 24
    assert co.decode.alloc.available == co.decode.alloc.num_blocks
