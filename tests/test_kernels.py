"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n", [
    (128, 256, 128),          # exactly one block
    (256, 512, 256),          # multi-block
    (200, 300, 130),          # ragged (padding path)
    (8, 1024, 8),             # skinny
    (384, 128, 512),
])
def test_int8_matmul_matches_ref(m, k, n):
    x = jnp.asarray(RNG.integers(-127, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-127, 128, (k, n), dtype=np.int8))
    out = ops.int8_matmul(x, w)
    ref = ops.int8_matmul_ref(x, w)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("bm,bk,bn", [(128, 128, 128), (128, 512, 256)])
def test_int8_matmul_block_shapes(bm, bk, bn):
    x = jnp.asarray(RNG.integers(-127, 128, (bm * 2, bk * 2), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-127, 128, (bk * 2, bn), dtype=np.int8))
    out = ops.int8_matmul(x, w, bm=bm, bk=bk, bn=bn)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ops.int8_matmul_ref(x, w)))


@pytest.mark.parametrize("m,k", [(256, 128), (100, 64), (8, 2048), (513, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowwise_quant_matches_ref(m, k, dtype):
    x = jnp.asarray(RNG.normal(size=(m, k)) * 3).astype(dtype)
    q, s = ops.rowwise_quant(x)
    qr, sr = ops.rowwise_quant_ref(x)
    # jit rewrites /const into *reciprocal -> ULP-level scale differences can
    # flip an exact .5 tie by one quantum on rare elements
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_rowwise_quant_roundtrip_bound():
    x = jnp.asarray(RNG.normal(size=(64, 128)).astype(np.float32))
    q, s = ops.rowwise_quant(x)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
    assert (err <= np.asarray(s) / 2 + 1e-7).all()


@pytest.mark.parametrize("bh,s,d", [(2, 256, 32), (4, 512, 64), (1, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, s, d, dtype):
    q = jnp.asarray(RNG.normal(size=(bh, s, d))).astype(dtype)
    k = jnp.asarray(RNG.normal(size=(bh, s, d))).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(bh, s, d))).astype(dtype)
    from repro.kernels.flash_attention import flash_attention_pallas
    out = flash_attention_pallas(q, k, v, bq=128, bk=128, interpret=True)
    ref = ops.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_gqa_wrapper():
    b, s, h, kv, d = 2, 256, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)).astype(np.float32))
    out = ops.flash_attention(q, k, v, bq=128, bk=128)
    kr = jnp.repeat(k, h // kv, axis=2)
    vr = jnp.repeat(v, h // kv, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = ops.flash_attention_ref(fold(q), fold(kr), fold(vr))
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_flash_attention_causality():
    """Perturbing future keys must not change earlier outputs."""
    bh, s, d = 1, 256, 32
    q = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32))
    from repro.kernels.flash_attention import flash_attention_pallas
    out1 = flash_attention_pallas(q, k, v, bq=128, bk=128, interpret=True)
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    out2 = flash_attention_pallas(q, k2, v2, bq=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)
