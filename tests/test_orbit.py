"""Orbit control plane: power-profile integrals, bucket invariants
(property-tested), energy-first dispatch under a low bucket,
eclipse deferral-then-completion, OrbitSpec JSON round-trip, live
add/retire/set_capacity (streams survive retirement), autoscaler
grow/shrink, and the decode-token energy accounting the bucket drains
against."""
import json

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import ScheduledPlan
from repro.launch.route import vision_fleet_spec
from repro.models import transformer as T
from repro.orbit import (EnergyBucket, OrbitPhase, OrbitSpec, PhaseSpec,
                         PowerProfile, ScalingPolicy, budget_j)
from repro.router import SLOClass
from repro.serving import FleetSpec, PoolSpec

from conftest import tiny_dense

PROMPT_LEN, MAX_NEW = 8, 6


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def lm_spec(n_pools=1, **pool_kw):
    kw = dict(capacity=1, max_window=4, max_wait_s=0.0, max_slots=3,
              prompt_len=PROMPT_LEN, max_new=MAX_NEW, backend="engine")
    kw.update(pool_kw)
    names = ["lm"] if n_pools == 1 else [f"lm-{i}" for i in range(n_pools)]
    return FleetSpec(pools=[PoolSpec(n, ("tpu_v5e_bf16",), **kw)
                            for n in names],
                     workload="transformer", seq_len=PROMPT_LEN)


def prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN))
                         ).astype(np.int32) for _ in range(n)]


SUN_ECL = PowerProfile([OrbitPhase("sunlit", 2.0, 10.0),
                        OrbitPhase("eclipse", 3.0, 1.0)])


# ---------------------------------------------------------------------------
# power profile + bucket
# ---------------------------------------------------------------------------
def test_power_profile_integrals():
    p = SUN_ECL
    assert p.period_s == 5.0
    assert p.orbit_average_w == pytest.approx(23.0 / 5.0)
    assert p.power_at(0.5) == 10.0 and p.power_at(2.5) == 1.0
    assert p.power_at(5.5) == 10.0                   # cyclic
    assert p.energy_between(0.0, 5.0) == pytest.approx(23.0)
    # partial phases + wrap: [1,2]=10, [2,5]=3, [5,7]=20
    assert p.energy_between(1.0, 7.0) == pytest.approx(33.0)
    # many whole cycles plus a partial
    assert p.energy_between(0.0, 10.0 + 1.5) == pytest.approx(61.0)
    assert p.energy_between(3.0, 3.0) == 0.0
    assert budget_j(p, 7.0, 0.0, 5.0) == pytest.approx(30.0)


@given(st.lists(st.tuples(st.floats(0.0, 5.0), st.floats(0.0, 50.0)),
                max_size=40))
@settings(deadline=None, max_examples=60)
def test_bucket_level_never_negative_never_over_capacity(ops):
    b = EnergyBucket(20.0, SUN_ECL, level_j=5.0)
    t = 0.0
    for dt, j in ops:
        t += dt
        b.advance(t)
        assert 0.0 <= b.level_j <= b.capacity_j
        b.drain(j)
        assert 0.0 <= b.level_j <= b.capacity_j
    # conservation: what was banked minus what was covered is the level
    assert b.level_j == pytest.approx(
        5.0 + (b.harvested_j - b.wasted_j) - (b.spent_j - b.shortfall_j))


def test_bucket_drain_and_clip_accounting():
    b = EnergyBucket(10.0, None, level_j=4.0)
    assert b.drain(6.0) == 4.0                       # covered only 4
    assert b.level_j == 0.0 and b.shortfall_j == 2.0
    b2 = EnergyBucket(10.0, SUN_ECL, level_j=10.0)
    b2.advance(1.0)                                  # full: harvest wasted
    assert b2.level_j == 10.0 and b2.wasted_j == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# energy-first dispatch when the bucket runs low
# ---------------------------------------------------------------------------
def test_conserve_mode_prefers_low_energy_plan():
    """Nominal dispatch buys latency slack with joules; conserve mode
    inverts that — the cheap slow plan wins even though a fast dear one
    has slack."""
    client = vision_fleet_spec().build()
    router = client.router
    fast = ScheduledPlan(((0, 6, "mpsoc_dpu"),), 0.1, 5.0, 0.0)
    cheap = ScheduledPlan(((0, 6, "mpsoc_dpu"),), 0.5, 1.0, 0.0)
    router.frontier = [fast, cheap]
    # budget 0.6: cheap fits but has no slack (0.5 > 0.6*0.6);
    # fast has slack (0.1 <= 0.36) at 5x the energy
    slo = SLOClass("eclipse-test", max_latency_s=0.6)
    plan_nominal, _ = router._choose(slo)
    router.energy_mode = "conserve"
    plan_conserve, _ = router._choose(slo)
    assert plan_nominal is fast
    assert plan_conserve is cheap


def test_controller_sets_router_mode_from_bucket():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 10.0, 0.0)],
                      bucket_j=10.0, initial_frac=0.3,
                      conserve_frac=0.5, critical_frac=0.05)
    ctrl = ospec.attach(client)
    assert ctrl.mode == "conserve"                   # honors initial level
    assert client.router.energy_mode == "conserve"
    ctrl.bucket.drain(ctrl.bucket.level_j)           # battery dry
    client.step()
    assert ctrl.mode == "critical"
    assert client.router.energy_mode == "conserve"   # router is two-state


# ---------------------------------------------------------------------------
# eclipse: defer offline work, keep critical flowing, complete at sunrise
# ---------------------------------------------------------------------------
def test_eclipse_defers_offline_then_completes():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 1.0, 0.0),
                              PhaseSpec("sunlit", 9.0, 100.0)],
                      bucket_j=1.0, initial_frac=0.2,
                      conserve_frac=0.5, critical_frac=0.01)
    ospec.attach(client)
    offline = client.submit(slo="bulk-reprocess")    # priority 0 -> parks
    critical = client.submit(slo="downlink-critical")
    assert offline.admitted and not offline.done
    assert offline.telemetry["deferred"] is True
    snap = client.telemetry
    assert snap["energy_deferred"] == 1
    assert snap["admitted"] == 1                     # only the critical one
    r_crit = critical.result()
    assert r_crit.latency_s < 0.9                    # served in the eclipse
    r_off = offline.result(max_s=30.0)
    assert r_off.admitted and not r_off.dropped
    assert r_off.latency_s > 0.9                     # waited for sunlight
    assert offline.telemetry["deferred"] is False
    assert client.telemetry["completed"] == 2


def test_critical_mode_rejects_only_when_battery_dry():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 100.0, 0.0)],
                      bucket_j=1.0, initial_frac=0.0,
                      conserve_frac=0.5, critical_frac=0.1)
    ospec.attach(client)
    offline = client.submit(slo="bulk-reprocess")
    assert offline.admitted and not offline.done     # deferred, not dropped
    critical = client.submit(slo="downlink-critical")
    assert not critical.admitted                     # dry bucket: last resort
    snap = client.telemetry
    assert snap["energy_rejected"] == 1 and snap["rejected"] == 1


# ---------------------------------------------------------------------------
# OrbitSpec round-trip
# ---------------------------------------------------------------------------
def test_orbit_spec_json_round_trip():
    ospec = OrbitSpec(
        phases=[PhaseSpec("sunlit", 60.0, 8.0),
                PhaseSpec("eclipse", 35.0, 1.5)],
        bucket_j=120.0, initial_frac=0.8, conserve_frac=0.4,
        critical_frac=0.1, hysteresis_frac=0.02, defer_max_priority=1,
        scaling=ScalingPolicy(template="board-a", max_pools=4,
                              queue_high=5, grow="capacity"))
    d = ospec.to_dict()
    restored = OrbitSpec.from_dict(json.loads(json.dumps(d)))
    assert restored.to_dict() == d
    assert restored == ospec                # field-by-field, not just dicts
    assert restored.hysteresis_frac == 0.02
    assert restored.scaling.template == "board-a"
    assert restored.scaling.grow == "capacity"
    assert (restored.profile().orbit_average_w
            == pytest.approx(ospec.profile().orbit_average_w))
    plain = OrbitSpec(phases=[PhaseSpec("sunlit", 1.0, 1.0)], bucket_j=1.0)
    assert OrbitSpec.from_dict(plain.to_dict()).scaling is None


def test_orbit_spec_validates_thresholds():
    with pytest.raises(ValueError, match="critical_frac"):
        OrbitSpec(phases=[PhaseSpec("s", 1.0, 1.0)], bucket_j=1.0,
                  conserve_frac=0.2, critical_frac=0.5)
    with pytest.raises(ValueError, match="storm_decay"):
        OrbitSpec(phases=[PhaseSpec("s", 1.0, 1.0)], bucket_j=1.0,
                  storm_decay=1.0)


# ---------------------------------------------------------------------------
# radiation-storm ladder: hardening-event pressure floors the mode
# ---------------------------------------------------------------------------
def test_storm_pressure_floors_mode_at_conserve():
    """A burst of hardening events (failover retries, watchdog trips,
    bitflips) floors the dispatch mode at conserve even on a full
    battery — and decays back to nominal once the storm passes."""
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("sunlit", 100.0, 1000.0)],
                      bucket_j=100.0, storm_events=1, storm_decay=0.8)
    ctrl = ospec.attach(client)
    assert ctrl.mode == "nominal"
    client.router.telemetry.retries += 3         # storm: retry burst
    client.step()
    assert ctrl.storm and ctrl.mode == "conserve"
    assert client.router.energy_mode == "conserve"
    assert ctrl.report()["storm_pressure"] > 0
    for _ in range(50):                          # no new events: decay out
        client.step()
        if ctrl.mode == "nominal":
            break
    assert ctrl.mode == "nominal" and not ctrl.storm
    # the storm knobs round-trip like every other orbit field
    d = ospec.to_dict()
    assert OrbitSpec.from_dict(json.loads(json.dumps(d))).to_dict() == d
    assert d["storm_events"] == 1 and d["storm_decay"] == 0.8


# ---------------------------------------------------------------------------
# live fleet mutation
# ---------------------------------------------------------------------------
def test_add_and_retire_pool_round_trip_costmodel():
    client = vision_fleet_spec().build()
    clone = PoolSpec("board-a/as0", ("mpsoc_dpu", "myriadx_vpu"),
                     capacity=2, max_window=4)
    client.add_pool(clone)
    assert "board-a/as0" in client.router.pools
    assert client.telemetry["pools_added"] == 1
    # downlink-critical rides the boards' DPU plans, so the clone's
    # profile set hosts it and least-loaded routing spreads onto it
    handles = [client.submit(slo="downlink-critical") for _ in range(12)]
    served_by_clone = sum(h._rreq.pool == "board-a/as0" for h in handles)
    assert served_by_clone > 0                       # clone takes traffic
    client.retire_pool("board-a/as0")
    client.drain()
    client.step()                                    # finalize retirement
    assert "board-a/as0" not in client.router.pools
    snap = client.telemetry
    assert snap["pools_retired"] == 1
    assert snap["completed"] == snap["admitted"] and snap["dropped"] == 0
    assert all(h.done for h in handles)
    # retired pool's counters stay in the snapshot as history
    assert snap["pools"]["board-a/as0"]["completed"] == served_by_clone


def test_retire_pool_never_drops_inflight_stream(model):
    spec = lm_spec(n_pools=2)
    client = spec.build(model=model)
    h = client.submit(prompts(1, seed=6)[0], slo="offline", max_new=MAX_NEW)
    victim = h._rreq.pool
    assert victim is not None
    client.retire_pool(victim)                       # mid-flight
    r = h.result()
    assert not r.dropped and r.tokens.shape == (MAX_NEW,)
    assert h.token_steps == sorted(h.token_steps)    # streamed in order
    client.step()
    assert victim not in client.router.pools
    assert client.telemetry["pools_retired"] == 1
    # the survivor still serves new traffic
    h2 = client.submit(prompts(1, seed=7)[0], slo="offline", max_new=2)
    assert h2.result().tokens.shape == (2,)


def test_add_engine_pool_live_reuses_fleet_model(model):
    client = lm_spec().build(model=model)
    client.add_pool(PoolSpec("lm/as0", ("tpu_v5e_bf16",), backend="engine",
                             capacity=1, max_window=4, max_wait_s=0.0,
                             max_slots=3, prompt_len=PROMPT_LEN,
                             max_new=MAX_NEW))
    assert "lm/as0" in client.engines
    # route enough traffic that the least-loaded split uses both pools,
    # and every stream still completes exactly
    handles = [client.submit(p, slo="offline", max_new=3)
               for p in prompts(6, seed=8)]
    client.drain()
    assert {h._rreq.pool for h in handles} == {"lm", "lm/as0"}
    for h in handles:
        assert h.result().tokens.shape == (3,)


def test_draining_pool_keeps_work_through_unrelated_fault():
    """A fault on a profile the queued work does not use must not evict
    it from a draining pool — retirement still never drops the work."""
    from repro.core.scheduler import plan_profiles
    from repro.router import SLO_CLASSES as ROUTER_SLOS

    client = vision_fleet_spec().build()
    router = client.router
    plan = next(p for p in router.frontier
                if plan_profiles(p) == {"mpsoc_dpu"})
    pool = router.pools["board-a"]
    from repro.router import RouterRequest
    req = RouterRequest(0, ROUTER_SLOS["downlink-critical"], 0.0, plan=plan)
    pool.enqueue(req, 0.0)
    pool.draining = True
    displaced = pool.degrade(("myriadx_vpu",))   # fault misses the plan
    assert displaced == [] and pool.load == 1    # work survives the drain
    assert not pool.compatible(plan)             # but no NEW work lands
    displaced = pool.degrade(("mpsoc_dpu",))     # now the fault hits it
    assert displaced == [req]
    assert pool.counters.load_now == 0           # counters track eviction


def test_attach_after_advance_banks_no_phantom_harvest():
    client = vision_fleet_spec().build()
    client.step(50.0)                            # fleet ran for 50 s first
    ospec = OrbitSpec(phases=[PhaseSpec("sunlit", 100.0, 1000.0)],
                      bucket_j=100.0, initial_frac=0.1)
    ctrl = ospec.attach(client)
    client.step()                                # one real tick (dt=0.002)
    # one tick harvests 2 J; crediting the pre-attach 50 s would have
    # slammed the bucket to capacity
    assert ctrl.bucket.level_j == pytest.approx(10.0 + 2.0)


def test_retire_guard_counts_dead_pools_as_gone():
    spec = FleetSpec(pools=[PoolSpec("a", ("mpsoc_dpu",)),
                            PoolSpec("b", ("mpsoc_dpu",))],
                     workload="ursonet")
    client = spec.build()
    client.router.pools["b"].degrade(())         # permanent SEU: b is DEAD
    with pytest.raises(ValueError, match="last live pool"):
        client.retire_pool("a")


def test_set_capacity_live():
    client = vision_fleet_spec().build()
    client.set_capacity("board-a", 5)
    assert client.router.pools["board-a"].capacity == 5
    with pytest.raises(ValueError, match="capacity"):
        client.set_capacity("board-a", 0)
    with pytest.raises(KeyError):
        client.set_capacity("nope", 2)


def test_router_guards_fleet_invariants():
    client = vision_fleet_spec().build()
    with pytest.raises(ValueError, match="already routed"):
        client.add_pool(PoolSpec("board-a", ("mpsoc_dpu",)))
    client.submit(slo="bulk-reprocess")
    loaded = next(p for p in client.router.pools.values() if p.load)
    with pytest.raises(ValueError, match="drain"):
        client.router.remove_pool(loaded.name)
    # a fleet cannot retire itself empty
    single = _burst_spec().build()
    with pytest.raises(ValueError, match="last live pool"):
        single.retire_pool("board")


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------
def _burst_spec():
    return FleetSpec(pools=[PoolSpec("board", ("mpsoc_dpu",), capacity=1,
                                     max_window=2, max_wait_s=0.0)],
                     workload="ursonet")


def test_autoscaler_grows_on_queue_then_retires_idle():
    client = _burst_spec().build()
    ospec = OrbitSpec(
        phases=[PhaseSpec("sunlit", 100.0, 1e9)], bucket_j=1e9,
        scaling=ScalingPolicy(template="board", min_pools=1, max_pools=3,
                              queue_high=4, queue_low=0, cooldown_s=0.01))
    ctrl = ospec.attach(client)
    handles = [client.submit(slo="bulk-reprocess") for _ in range(16)]
    client.drain()
    for _ in range(200):                             # idle tail
        client.step()
    snap = client.telemetry
    assert snap["pools_added"] >= 1
    assert snap["pools_retired"] == snap["pools_added"]   # back to baseline
    assert list(client.router.pools) == ["board"]
    assert snap["completed"] == 16 and snap["dropped"] == 0
    assert all(h.done for h in handles)
    ops = [a["op"] for a in ctrl.autoscaler.actions]
    assert "add" in ops and "retire" in ops


def test_autoscaler_capacity_mode():
    client = _burst_spec().build()
    ospec = OrbitSpec(
        phases=[PhaseSpec("sunlit", 100.0, 1e9)], bucket_j=1e9,
        scaling=ScalingPolicy(template="board", queue_high=4, queue_low=0,
                              cooldown_s=0.01, grow="capacity",
                              max_capacity=4))
    ospec.attach(client)
    for _ in range(16):
        client.submit(slo="bulk-reprocess")
    client.drain()
    pool = client.router.pools["board"]
    assert pool.capacity > 1                         # grew under the burst
    for _ in range(400):
        client.step()
    assert pool.capacity == 1                        # shrank back when idle


def test_autoscaler_suppresses_growth_off_nominal():
    client = _burst_spec().build()
    ospec = OrbitSpec(
        phases=[PhaseSpec("eclipse", 100.0, 0.0)], bucket_j=1.0,
        initial_frac=0.2,                            # starts in conserve
        scaling=ScalingPolicy(template="board", queue_high=2,
                              cooldown_s=0.0))
    ctrl = ospec.attach(client)
    for _ in range(8):
        client.submit(slo="downlink-critical")       # non-deferrable load
    for _ in range(20):
        client.step()
    assert ctrl.mode != "nominal"
    assert client.telemetry["pools_added"] == 0      # no growth in eclipse


# ---------------------------------------------------------------------------
# energy accounting the bucket drains against (executor fix)
# ---------------------------------------------------------------------------
def test_engine_energy_scales_with_decoded_tokens(model):
    client = lm_spec().build(model=model)
    long_h = client.submit(prompts(1, seed=3)[0], slo="offline",
                           max_new=MAX_NEW)
    short_h = client.submit(prompts(1, seed=4)[0], slo="offline", max_new=1)
    client.drain()
    assert long_h.result().tokens.shape == (MAX_NEW,)
    assert short_h.result().tokens.shape == (1,)
    plan_e = long_h._rreq.plan.energy_j
    # one token per request comes from the admission prefill; energy is
    # charged per *decoded* token — max_new=1 decodes none, max_new=6
    # decodes five — not per request
    decoded = MAX_NEW - 1
    counters = client.router.telemetry.pools["lm"]
    assert counters.decode_tokens == decoded
    assert counters.energy_j == pytest.approx(plan_e * decoded, rel=1e-6)


def test_prepay_charges_expected_energy_then_reconciles(model):
    """Admission-time prepay: the bucket dips by the expected plan
    energy the moment a request dispatches, and nets back to the real
    metered spend once it settles."""
    client = lm_spec().build(model=model)
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 1000.0, 0.0)],
                      bucket_j=1000.0, conserve_frac=0.01,
                      critical_frac=0.001)
    ctrl = ospec.attach(client)
    level0 = ctrl.bucket.level_j
    floor = min(p.energy_j for p in client.router.frontier)
    client.submit(prompts(1, seed=9)[0], slo="offline", max_new=MAX_NEW)
    # charged at submit, before any token decoded
    assert ctrl.report()["prepaid_j"] == pytest.approx(
        floor * MAX_NEW, abs=5e-7)      # report() rounds to 6 places
    assert ctrl.bucket.level_j == pytest.approx(
        level0 - floor * MAX_NEW, rel=1e-6)
    client.drain()
    client.step()                          # reconcile sweep
    assert not ctrl._prepaid
    assert ctrl.report()["prepaid_j"] == 0.0
    real = sum(c.energy_j
               for c in client.router.telemetry.pools.values())
    assert real > 0
    # prepay + refund nets out: the bucket's books show the metered
    # spend only (zero-harvest profile, so level moves by exactly it)
    assert ctrl.bucket.spent_j == pytest.approx(real, rel=1e-6)
    assert ctrl.bucket.level_j == pytest.approx(level0 - real, rel=1e-6)


def test_failover_reserve_is_not_recharged(model):
    """A re-dispatched batch whose output the engine already holds
    decodes nothing — and must charge (almost) nothing."""
    client = lm_spec(n_pools=2).build(model=model)
    h = client.submit(prompts(1, seed=5)[0], slo="offline", max_new=4)
    client.drain()
    pool = h._rreq.pool
    counters = client.router.telemetry.pools[pool]
    e_first = counters.energy_j
    assert e_first > 0
    # re-run the same rid through the executor (failover re-dispatch path)
    ex = client.router.pools[pool].executor
    lat, energy = ex.run(h._rreq.plan, [h._rreq])
    assert energy == 0.0                             # no decode, no charge


# ---------------------------------------------------------------------------
# one telemetry schema (satellite: fleet energy + live queue depth)
# ---------------------------------------------------------------------------
def test_snapshot_surfaces_fleet_energy_and_live_queues():
    client = vision_fleet_spec().build()
    for _ in range(6):
        client.submit(slo="bulk-reprocess")
    snap_mid = client.telemetry
    assert snap_mid["queue_depth"] >= 1              # live, pre-drain
    client.drain()
    snap = client.telemetry
    assert snap["queue_depth"] == 0
    assert snap["energy_j"] == pytest.approx(
        sum(p["energy_j"] for p in snap["pools"].values()), abs=1e-3)
    for p in snap["pools"].values():
        assert "queue_depth_now" in p and "load_now" in p
    for key in ("energy_deferred", "energy_rejected", "pools_added",
                "pools_retired"):
        assert key in snap
    json.dumps(snap)                                 # stays serializable


# ---------------------------------------------------------------------------
# fail-fast OrbitSpec validation (fleetlint PR)
# ---------------------------------------------------------------------------
def test_orbit_validate_rejects_empty_phases():
    spec = OrbitSpec(phases=[], bucket_j=100.0)
    with pytest.raises(ValueError, match="at least one PhaseSpec"):
        spec.validate()


@pytest.mark.parametrize("kw,match", [
    (dict(phases=[PhaseSpec("sunlit", 0.0, 8.0)], bucket_j=10.0),
     "duration_s"),
    (dict(phases=[PhaseSpec("sunlit", 60.0, -1.0)], bucket_j=10.0),
     "power_w"),
    (dict(phases=[PhaseSpec("sunlit", 60.0, 8.0)], bucket_j=0.0),
     "bucket_j"),
    (dict(phases=[PhaseSpec("sunlit", 60.0, 8.0)], bucket_j=10.0,
          initial_frac=1.5), "initial_frac"),
])
def test_orbit_validate_rejects_bad_field(kw, match):
    with pytest.raises(ValueError, match=match):
        OrbitSpec(**kw).validate()


def test_orbit_attach_validates():
    client = vision_fleet_spec().build()
    bad = OrbitSpec(phases=[PhaseSpec("sunlit", 60.0, 8.0)], bucket_j=-5.0)
    with pytest.raises(ValueError, match="bucket_j"):
        bad.attach(client)


def test_orbit_from_dict_rejects_unknown_keys():
    spec = OrbitSpec(phases=[PhaseSpec("sunlit", 60.0, 8.0)],
                     bucket_j=120.0)
    d = spec.to_dict()
    d["bucket_joules"] = 1.0
    with pytest.raises(ValueError, match=r"OrbitSpec.*bucket_joules"):
        OrbitSpec.from_dict(d)
    d = spec.to_dict()
    d["phases"][0]["duration"] = 60.0
    with pytest.raises(ValueError, match=r"PhaseSpec.*duration"):
        OrbitSpec.from_dict(d)
    assert OrbitSpec.from_dict(spec.to_dict()).to_dict() == spec.to_dict()
