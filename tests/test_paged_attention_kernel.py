"""Pallas paged-decode-attention kernel vs the gather_kv reference path
(interpret mode): ragged lengths, partially-filled blocks, GQA head
layouts, and the trash-row contract for inactive batch slots."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention_pallas
from repro.runtime.paging import (BlockAllocator, append_tokens,
                                  ensure_blocks, init_paged_cache,
                                  paged_decode_attention)

RNG = np.random.default_rng(7)

IMPLS = {
    "pallas": lambda *a: paged_attention_pallas(*a, interpret=True),
    "xla": ops.paged_attention_xla,
}


def _ragged_state(lengths, block_size, kv_heads, head_dim, num_blocks,
                  dtype=jnp.float32, max_blocks=None):
    """Build a paged cache holding random KV at the given ragged lengths,
    allocated out of a shuffled free list (non-contiguous block rows)."""
    b = len(lengths)
    alloc = BlockAllocator(num_blocks)
    RNG.shuffle(alloc.free)                       # rows land out of order
    state = init_paged_cache(b, num_blocks, block_size, kv_heads, head_dim,
                             dtype=dtype, max_blocks=max_blocks)
    for t in range(max(lengths)):
        grow = np.array([1 if t < L else 0 for L in lengths])
        state = ensure_blocks(state, alloc, grow)
        k = RNG.normal(size=(b, kv_heads, head_dim)).astype(np.float32)
        v = RNG.normal(size=(b, kv_heads, head_dim)).astype(np.float32)
        # only sequences still growing get a real write; freeze others by
        # writing then restoring is overkill — instead append to all and
        # rebuild lengths below (append_tokens advances every valid row)
        state = append_tokens(state, jnp.asarray(k), jnp.asarray(v))
        state = state._replace(lengths=jnp.asarray(
            np.minimum(np.asarray(state.lengths), lengths)))
    np.testing.assert_array_equal(np.asarray(state.lengths), lengths)
    return state


@pytest.mark.parametrize("lengths,block_size", [
    ([9, 1, 6], 4),            # ragged, partial last blocks
    ([8, 8], 4),               # exactly block-aligned
    ([1, 1, 1, 1], 8),         # single token, mostly-empty blocks
    ([33, 7, 20, 15], 8),      # multi-block walks
])
@pytest.mark.parametrize("kv,gp", [(2, 2), (1, 4), (4, 1)])
@pytest.mark.parametrize("impl", sorted(IMPLS))
def test_kernel_matches_gather_reference(lengths, block_size, kv, gp, impl):
    hd = 16
    state = _ragged_state(lengths, block_size, kv, hd, num_blocks=32)
    q = jnp.asarray(RNG.normal(size=(len(lengths), kv, gp, hd))
                    .astype(np.float32))
    out = IMPLS[impl](q, state.k_pool, state.v_pool,
                      state.block_table, state.lengths)
    ref = paged_decode_attention(q, state, max_len=max(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-2)


@pytest.mark.parametrize("impl", sorted(IMPLS))
def test_kernel_matches_reference_bf16_pool(impl):
    lengths = [11, 3]
    state = _ragged_state(lengths, 4, 2, 16, num_blocks=16,
                          dtype=jnp.bfloat16)
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 16)).astype(np.float32))
    out = IMPLS[impl](q, state.k_pool, state.v_pool,
                      state.block_table, state.lengths)
    ref = paged_decode_attention(q, state, max_len=11)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-2)


def test_kernel_ignores_blocks_past_length():
    """Garbage in a sequence's unallocated/dead region must not leak in."""
    lengths = [5, 2]
    state = _ragged_state(lengths, 4, 2, 8, num_blocks=16)
    ref_q = jnp.asarray(RNG.normal(size=(2, 2, 2, 8)).astype(np.float32))
    ref = np.asarray(ops.paged_attention(ref_q, state.k_pool, state.v_pool,
                                         state.block_table, state.lengths))
    # poison every pool row not referenced within a live prefix
    table = np.asarray(state.block_table)
    live = set()
    for b, L in enumerate(lengths):
        live |= set(table[b, :-(-L // 4)].tolist())
    pk = np.asarray(state.k_pool).copy()
    pv = np.asarray(state.v_pool).copy()
    for row in range(pk.shape[0]):
        if row not in live:
            pk[row] = 1e4
            pv[row] = 1e4
    state = state._replace(k_pool=jnp.asarray(pk), v_pool=jnp.asarray(pv))
    out = np.asarray(ops.paged_attention(ref_q, state.k_pool, state.v_pool,
                                         state.block_table, state.lengths))
    np.testing.assert_allclose(out, ref, atol=1e-2)


@pytest.mark.parametrize("impl", sorted(IMPLS))
def test_zero_length_slot_attends_to_nothing(impl):
    """A slot with lengths == 0 (freed / never admitted) must return
    exactly 0 from both implementations — not leak pool row 0."""
    state = _ragged_state([6, 1], 4, 2, 8, num_blocks=16)
    state = state._replace(lengths=jnp.asarray(np.array([6, 0], np.int32)))
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 8)).astype(np.float32))
    out = np.asarray(IMPLS[impl](q, state.k_pool, state.v_pool,
                                 state.block_table, state.lengths))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[1], np.zeros_like(out[1]))


def test_inactive_slot_appends_to_trash_row():
    """A batch slot with no allocated blocks writes to the trash row and
    its length does not advance — live blocks stay untouched."""
    alloc = BlockAllocator(8)
    state = init_paged_cache(2, 8, 4, 2, 8, dtype=jnp.float32)
    state = ensure_blocks(state, alloc, np.array([4, 0]))   # slot 1 inactive
    k = jnp.asarray(RNG.normal(size=(2, 2, 8)).astype(np.float32))
    before = np.asarray(state.k_pool[:-1]).copy()           # live rows
    state = append_tokens(state, k, k)
    np.testing.assert_array_equal(np.asarray(state.lengths), [1, 0])
    after = np.asarray(state.k_pool[:-1])
    # only slot 0's first block changed; slot 1's write went to trash
    row0 = int(np.asarray(state.block_table)[0, 0])
    changed = [r for r in range(after.shape[0])
               if not np.array_equal(before[r], after[r])]
    assert changed == [row0], changed
