"""Multi-device behaviour via subprocesses (8 faked host devices) — keeps
the main test process at 1 device.  Covers: the MPAI two-stage
co-processing pipeline vs monolithic forward, int8-compressed gradient
collectives vs exact mean, and a sharded train step on a (2,2,2) mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src"}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_two_stage_pipeline_matches_monolithic():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig, MeshConfig
        from repro.core.partition import PartitionPlan, Segment
        from repro.core.precision import PrecisionPolicy
        from repro.core.pipeline import (lm_two_stage_fns, pipeline_apply,
                                         split_lm_params_for_stages)
        from repro.models import transformer as T
        from repro.models.layers import embed

        cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=32,
                          num_heads=4, num_kv_heads=4, d_ff=64,
                          vocab_size=128, remat=False)
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        plan = PartitionPlan((
            Segment("backbone", 0, 2, PrecisionPolicy.bf16()),
            Segment("head", 2, 4, PrecisionPolicy.bf16())))

        mesh = jax.make_mesh((2, 4), ("stage", "model"))
        s0, s1, _ = lm_two_stage_fns(cfg, plan)
        sp = split_lm_params_for_stages(params, cfg, plan, 1)

        n_micro, b, s = 3, 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (n_micro, b, s),
                                  0, 128)
        embeds = jnp.stack([embed(params["embed"], toks[i])
                            for i in range(n_micro)])
        outs = pipeline_apply(mesh, "stage", [s0, s1], sp, embeds,
                              hidden_shape=(b, s, 32),
                              out_shape=(b, s, 128))
        ref = jnp.stack([T.forward(params, cfg, toks[i]).logits
                         for i in range(n_micro)])
        d = float(jnp.max(jnp.abs(outs - ref.astype(outs.dtype))))
        assert d < 0.05, d
        print("pipeline ok", d)
    """)
    assert "pipeline ok" in out


def test_compressed_grad_mean_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_grad_mean, CHUNK

        # jax.shard_map (check_vma=) is the renamed
        # jax.experimental.shard_map.shard_map (check_rep=)
        if hasattr(jax, "shard_map"):
            shard_map = partial(jax.shard_map, check_vma=False)
        else:
            from jax.experimental.shard_map import shard_map
            shard_map = partial(shard_map, check_rep=False)

        mesh = jax.make_mesh((8,), ("pod",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                        (8, CHUNK * 2)),
                 "b": jax.random.normal(jax.random.PRNGKey(1), (8, 4))}

        @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P())
        def comp(g):
            g = jax.tree.map(lambda a: a[0], g)
            return compressed_grad_mean(g, "pod")

        got = comp(grads)
        want = jax.tree.map(lambda a: jnp.mean(a, 0), grads)
        # int8-compressed large leaf: close; small leaf: exact fp32 pmean
        rel = (jnp.abs(got["w"] - want["w"]).max()
               / jnp.abs(want["w"]).max())
        assert float(rel) < 0.05, rel
        np.testing.assert_allclose(np.asarray(got["b"]),
                                   np.asarray(want["b"]), rtol=1e-5)
        print("compression ok", float(rel))
    """)
    assert "compression ok" in out


def test_sharded_train_step_on_222_mesh():
    out = _run("""
        import jax, numpy as np
        from repro.configs.base import (MeshConfig, ModelConfig, ShapeConfig,
                                        TrainConfig)
        from repro.data.pipeline import lm_batch
        from repro.runtime.train_loop import Trainer

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=256, remat=False, fsdp=True)
        shape = ShapeConfig("t", 32, 8, "train")
        mesh_cfg = MeshConfig((2, 2, 2), ("pod", "data", "model"))
        tr = Trainer(cfg, shape, mesh_cfg, TrainConfig(learning_rate=1e-2))
        state = tr.init_state()
        losses = []
        for s in range(10):
            state, m = tr.step_fn(state, lm_batch(cfg, shape, s))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("sharded train ok", losses[0], "->", losses[-1])
    """)
    assert "sharded train ok" in out


def test_elastic_restart_across_mesh_shapes():
    """Save on a (2,4) mesh, restore on (1,8) and (4,2) — resharding works
    and parameters are bitwise identical."""
    out = _run("""
        import jax, numpy as np, tempfile
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs.base import (MeshConfig, ModelConfig, ShapeConfig,
                                        TrainConfig)
        from repro.data.pipeline import lm_batch
        from repro.runtime.train_loop import Trainer

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=8, num_kv_heads=8, d_ff=128,
                          vocab_size=256, remat=False)
        shape = ShapeConfig("t", 32, 8, "train")
        tc = TrainConfig()
        tmp = tempfile.mkdtemp()
        tr = Trainer(cfg, shape, MeshConfig((2, 4), ("data", "model")), tc)
        state = tr.init_state()
        state, _ = tr.run(state, lambda s: lm_batch(cfg, shape, s), 3)
        mgr = CheckpointManager(tmp)
        mgr.save(3, state, blocking=True)

        for ms in [((1, 8)), ((4, 2))]:
            tr2 = Trainer(cfg, shape, MeshConfig(ms, ("data", "model")), tc)
            like = jax.eval_shape(tr2._init_state, jax.random.PRNGKey(0))
            restored, step = mgr.restore(like,
                                         shardings=tr2.state_shardings)
            assert step == 3
            for a, b in zip(jax.tree_util.tree_leaves(state.params),
                            jax.tree_util.tree_leaves(restored.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            restored, _ = tr2.step_fn(restored,
                                      lm_batch(cfg, shape, 3))
        print("elastic ok")
    """)
    assert "elastic ok" in out
