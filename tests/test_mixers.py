"""Mixer-level oracles: chunked scans vs naive per-token recurrences, MoE
dispatch semantics, attention paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MambaConfig, ModelConfig, MoEConfig, RWKVConfig
from repro.models import attention as A
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv as R

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Mamba: chunked scan vs naive recurrence
# ---------------------------------------------------------------------------
def _mamba_cfg():
    return ModelConfig(name="m", family="ssm", num_layers=1, d_model=32,
                       num_heads=0, num_kv_heads=0, d_ff=64, vocab_size=64,
                       mixer="mamba", mamba=MambaConfig(d_state=4),
                       remat=False)


def test_mamba_chunked_matches_stepwise():
    cfg = _mamba_cfg()
    params = M.mamba_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 19, 32),
                          jnp.float32) * 0.5
    y_seq = M.mamba_apply(params, cfg, x, chunk=8)
    # naive: token-by-token decode steps
    cache = M.init_mamba_cache(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        y, cache = M.mamba_decode_step(params, cfg, x[:, t:t + 1], cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_step, np.float32), atol=2e-2)


def test_mamba_state_handoff_across_chunks():
    cfg = _mamba_cfg()
    params = M.mamba_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 32), jnp.float32)
    y8 = M.mamba_apply(params, cfg, x, chunk=8)
    y16 = M.mamba_apply(params, cfg, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y8, np.float32),
                               np.asarray(y16, np.float32), atol=2e-2)


# ---------------------------------------------------------------------------
# RWKV: chunked wkv vs naive recurrence
# ---------------------------------------------------------------------------
def test_rwkv_wkv_chunked_matches_naive():
    b, t, h, n = 2, 21, 3, 8
    rng = np.random.default_rng(0)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(b, t, h, n)) - 1))
                    .astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, n)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, t, h, n)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(b, t, h, n)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, n)).astype(np.float32))
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    y_chunk, s_chunk = R._wkv_chunk_scan(s0, w, k, v, r, u, chunk=5)
    # naive
    s = np.zeros((b, h, n, n), np.float32)
    ys = np.zeros((b, t, h, n), np.float32)
    wn, kn, vn, rn, un = map(np.asarray, (w, k, v, r, u))
    for tt in range(t):
        kv = kn[:, tt, :, :, None] * vn[:, tt, :, None, :]
        ys[:, tt] = np.einsum("bhn,bhnm->bhm", rn[:, tt],
                              s + un[None, :, :, None] * kv)
        s = wn[:, tt][..., None] * s + kv
    np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), s, atol=1e-4)


def test_rwkv_head_padding_exact_zeros():
    cfg = ModelConfig(name="r", family="ssm", num_layers=1, d_model=48,
                      num_heads=0, num_kv_heads=0, d_ff=64, vocab_size=64,
                      mixer="rwkv6",
                      rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=8),
                      remat=False)
    # 3 heads, pad to 4 at tp=4
    params = R.time_mix_init(KEY, cfg, tp=4)
    hp, n, dp = R.rwkv_dims(cfg, 4)
    assert (hp, dp) == (4, 64)
    x = jax.random.normal(KEY, (1, 8, 48), jnp.float32)
    out, s_fin, _ = R.time_mix_apply(params, cfg, x, jnp.zeros((1, 48)), tp=4)
    assert out.shape == (1, 8, 48)
    # padded head's state stays zero (k projection is zero there)
    assert float(jnp.abs(s_fin[:, 3]).max()) == 0.0


# ---------------------------------------------------------------------------
# Attention: head-padding layouts + chunked == full
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,kv,tp,exp", [
    (40, 8, 16, (48, 16, 3)),     # qwen3
    (48, 4, 16, (48, 16, 3)),     # starcoder2
    (24, 24, 16, (48, 48, 1)),    # musicgen
    (128, 8, 16, (128, 16, 8)),   # llama3
    (32, 8, 16, (32, 16, 2)),     # jamba/llava
    (32, 32, 16, (32, 32, 1)),    # stablelm
    (16, 16, 16, (16, 16, 1)),    # olmoe
    (32, 8, 1, (32, 8, 4)),       # no TP -> no padding
])
def test_head_layouts(h, kv, tp, exp):
    lay = A.head_layout(h, kv, tp)
    assert (lay.Hp, lay.KVp, lay.gp) == exp
    assert lay.Hp % tp == 0 and lay.KVp % tp == 0 and lay.Hp % lay.KVp == 0
    # every real q head appears exactly once in the padded layout
    smap = np.asarray(A.q_slot_map(lay))
    real = smap[smap >= 0]
    assert sorted(real.tolist()) == list(range(h))


def test_padded_attention_matches_unpadded():
    """tp padding must not change the math."""
    cfg = ModelConfig(name="a", family="dense", num_layers=1, d_model=40,
                      num_heads=5, num_kv_heads=5, d_ff=64, vocab_size=64,
                      head_dim=8, remat=False)
    p1 = A.attention_init(KEY, cfg, tp=1)
    p4 = A.attention_init(KEY, cfg, tp=4)   # 5 heads -> 20 padded
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 40), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(12), (2, 12))
    np.testing.assert_allclose(
        np.asarray(A.attention_apply(p1, cfg, x, pos), np.float32),
        np.asarray(A.attention_apply(p4, cfg, x, pos), np.float32),
        atol=1e-3)


def test_chunked_attention_matches_full():
    b, s, kvp, gp, hd = 2, 64, 2, 2, 16
    q = jax.random.normal(KEY, (b, s, kvp, gp, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvp, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvp, hd), jnp.float32)
    pos = jnp.arange(s)
    full = A.full_attention(q, k, v, pos, pos)
    chunked = A.chunked_attention(q, k, v, pos, pos, chunk=16)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(chunked, np.float32), atol=1e-5)
    # windowed too
    fullw = A.full_attention(q, k, v, pos, pos, window=7)
    chunkw = A.chunked_attention(q, k, v, pos, pos, window=7, chunk=16)
    np.testing.assert_allclose(np.asarray(fullw, np.float32),
                               np.asarray(chunkw, np.float32), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------
def _moe(e=4, k=2, cap=1.25):
    return MoEConfig(num_experts=e, top_k=k, d_ff_expert=32,
                     capacity_factor=cap)


def test_moe_dropless_small_batch_routes_everything():
    moe = _moe()
    params = MoE.moe_init(KEY, 16, moe, glu=True)
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    out, aux = MoE.moe_apply(params, moe, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and float(aux) > 0


def test_moe_matches_dense_expert_sum():
    """Dropless top-k output == explicit per-token expert mixture."""
    moe = _moe(e=4, k=2)
    d = 16
    params = MoE.moe_init(KEY, d, moe, glu=True)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 6, d), jnp.float32)
    out, _ = MoE.moe_apply(params, moe, x)
    # naive reference
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(2):
            e = int(ids[t, j])
            h = xf[t] @ params["w_in"][e]
            hg = jax.nn.silu(xf[t] @ params["w_gate"][e]) * h
            ref[t] += float(gate[t, j]) * np.asarray(hg @ params["w_out"][e])
    # moe_apply computes in bf16; the naive reference is fp32
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d), np.float32),
                               ref, atol=6e-2)


def test_moe_capacity_drops_overflow():
    """Above-capacity assignments are dropped, not mis-routed."""
    moe = _moe(e=2, k=1, cap=0.5)
    d = 8
    params = MoE.moe_init(KEY, d, moe, glu=True)
    # >4096 assignments forces the capacity path
    x = jax.random.normal(KEY, (1, 8192, d), jnp.float32)
    out, _ = MoE.moe_apply(params, moe, x)
    assert out.shape == x.shape
    # with cap=0.5 roughly half the tokens get zero output
    zero_frac = float(jnp.mean(jnp.all(out == 0, axis=-1)))
    assert 0.2 < zero_frac < 0.8
