"""The paper's central empirical claim at test scale: partition-aware
training (QAT int8 backbone + high-precision head) serves at near-baseline
accuracy, while post-training quantization of everything degrades.

Uses a tiny UrsoNet + short training so the suite stays fast; the full
comparison is benchmarks/table1_ursonet.py."""
import pytest

from repro.models.cnn import UrsoNetConfig
from repro.pose import eval_ursonet, run_condition, train_ursonet
from repro.core.precision import PrecisionPolicy

CFG = UrsoNetConfig(name="test", image_hw=(48, 64), widths=(8, 16),
                    blocks_per_stage=1, fc_dim=32)


@pytest.fixture(scope="module")
def fp32_trained():
    # 250 steps: at 120 the tiny model's noisy per-batch loss sits right
    # at the 0.7x bar (0.71-0.73 across lrs) — this is the smallest run
    # that clears it with margin
    return train_ursonet(CFG, PrecisionPolicy.bf16(), PrecisionPolicy.fp32(),
                         steps=250, batch=16)


def test_training_reduces_loss(fp32_trained):
    _, hist = fp32_trained
    assert hist[-1][1] < hist[0][1] * 0.7, hist


def test_int8_serving_of_fp32_model_changes_outputs(fp32_trained):
    """PTQ: int8-everything serving visibly perturbs a model not trained
    for it (at this tiny scale the perturbation can cut either way on a
    noisy metric; the systematic degradation is asserted at benchmark
    scale — table1_ursonet).  Here: outputs must differ measurably, and
    not be wildly better (which would indicate a broken eval path)."""
    params, _ = fp32_trained
    base = eval_ursonet(params, CFG, PrecisionPolicy.bf16(),
                        PrecisionPolicy.fp32(), batches=4)
    ptq = eval_ursonet(params, CFG, PrecisionPolicy.int8(),
                       PrecisionPolicy.int8(), batches=4)
    score = lambda m: m[0] + m[1] / 100
    assert abs(score(ptq) - score(base)) > 1e-4     # int8 changed outputs
    assert score(ptq) > score(base) - 0.3           # and is no free lunch


def test_mpai_partition_close_to_baseline(fp32_trained):
    """MPAI condition: QAT backbone + high-precision head ~= baseline,
    and closer than PTQ-everything."""
    params_fp32, _ = fp32_trained
    base = eval_ursonet(params_fp32, CFG, PrecisionPolicy.bf16(),
                        PrecisionPolicy.fp32(), batches=4)
    ptq = eval_ursonet(params_fp32, CFG, PrecisionPolicy.int8(),
                       PrecisionPolicy.int8(), batches=4)
    params_mpai, _ = train_ursonet(CFG, PrecisionPolicy.int8_qat(),
                                   PrecisionPolicy.bf16(), steps=250,
                                   batch=16)
    mpai = eval_ursonet(params_mpai, CFG, PrecisionPolicy.int8(),
                        PrecisionPolicy.bf16(), batches=4)
    score = lambda m: m[0] + m[1] / 100        # LOCE + ORIE blend
    # MPAI within a modest margin of its own baseline-condition score and
    # not worse than serving the fp32 model fully quantized
    assert score(mpai) <= score(ptq) + 0.05
    assert score(mpai) <= score(base) * 1.5 + 0.1
