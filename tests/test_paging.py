"""Paged KV cache: allocator behaviour + attention equivalence vs the
dense cache path."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.paging import (BlockAllocator, PagedKVState,
                                  append_tokens, ensure_blocks, gather_kv,
                                  init_paged_cache, paged_decode_attention,
                                  release_sequence)

B, P, KV, HD = 3, 4, 2, 8


def _write_tokens(state, alloc, n, seed=0):
    rng = np.random.default_rng(seed)
    ks, vs = [], []
    for t in range(n):
        state = ensure_blocks(state, alloc, np.ones(B, np.int64))
        k = jnp.asarray(rng.normal(size=(B, KV, HD)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, KV, HD)).astype(np.float32))
        state = append_tokens(state, k, v)
        ks.append(k)
        vs.append(v)
    return state, jnp.stack(ks, 1), jnp.stack(vs, 1)   # [B, n, KV, HD]


def test_gather_reconstructs_written_tokens():
    alloc = BlockAllocator(32)
    state = init_paged_cache(B, 32, P, KV, HD, dtype=jnp.float32)
    state, ks, vs = _write_tokens(state, alloc, 10)
    k, v, valid = gather_kv(state, 12)
    np.testing.assert_allclose(np.asarray(k[:, :10]), np.asarray(ks),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(v[:, :10]), np.asarray(vs),
                               atol=1e-6)
    assert bool(valid[:, :10].all()) and not bool(valid[:, 10:].any())


def test_paged_attention_matches_dense():
    alloc = BlockAllocator(32)
    state = init_paged_cache(B, 32, P, KV, HD, dtype=jnp.float32)
    state, ks, vs = _write_tokens(state, alloc, 9)
    gp = 2
    q = jnp.asarray(np.random.default_rng(1).normal(
        size=(B, KV, gp, HD)).astype(np.float32))
    out = paged_decode_attention(q, state, max_len=12)
    # dense reference
    scores = jnp.einsum("bkgd,btkd->bkgt", q, ks) / math.sqrt(HD)
    w = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgt,btkd->bkgd", w, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_allocator_reuse_after_release():
    alloc = BlockAllocator(8)
    state = init_paged_cache(2, 8, P, KV, HD)
    state = ensure_blocks(state, alloc, np.array([P * 3, P * 3]))
    assert alloc.available == 2
    state = release_sequence(state, alloc, 0)
    assert alloc.available == 5
    assert int(state.lengths[0]) == 0
    # freed blocks are reusable by the other sequence (has 3, needs 4)
    state = ensure_blocks(state, alloc, np.array([0, P * 4]))
    assert alloc.available == 4


def test_pool_exhaustion_raises():
    alloc = BlockAllocator(2)
    state = init_paged_cache(1, 2, P, KV, HD)
    with pytest.raises(RuntimeError, match="exhausted"):
        ensure_blocks(state, alloc, np.array([P * 3]))


def test_exhaustion_is_typed_and_atomic():
    from repro.runtime.paging import OutOfBlocksError
    alloc = BlockAllocator(3)
    state = init_paged_cache(2, 3, P, KV, HD)
    state = ensure_blocks(state, alloc, np.array([P, 0]))
    with pytest.raises(OutOfBlocksError):
        # needs 1 + 3 more blocks, only 2 left — nothing may leak, not
        # even seq 0's satisfiable share
        ensure_blocks(state, alloc, np.array([P * 2, P * 3]))
    assert alloc.available == 2
    assert int((np.asarray(state.block_table) >= 0).sum()) == 1


def test_write_prefill_roundtrips_through_gather():
    from repro.runtime.paging import write_prefill
    alloc = BlockAllocator(8)
    state = init_paged_cache(2, 8, P, KV, HD, dtype=jnp.float32)
    s = 7                                     # partial last block
    state = ensure_blocks(state, alloc, np.array([s, 0]))
    rng = np.random.default_rng(5)
    k = jnp.asarray(rng.normal(size=(s, KV, HD)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(s, KV, HD)).astype(np.float32))
    state = write_prefill(state, k, v, 0)
    assert int(state.lengths[0]) == s and int(state.lengths[1]) == 0
    gk, gv, valid = gather_kv(state, 8)
    np.testing.assert_allclose(np.asarray(gk[0, :s]), np.asarray(k),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gv[0, :s]), np.asarray(v),
                               atol=1e-6)
    assert bool(valid[0, :s].all()) and not bool(valid[0, s:].any())
