"""int8 KV cache (§Perf iteration): numerics + shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.attention import _cache_deq, _cache_quant


def test_cache_quant_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64)) * 3
    q, s = _cache_quant(x)
    err = np.abs(np.asarray(_cache_deq(q, s)) - np.asarray(x))
    assert err.max() <= float(s.max()) / 2 + 1e-6
    assert q.dtype == jnp.int8 and s.shape == (2, 8, 4, 1)


def test_int8_cache_decode_close_to_forward():
    cfg = get_config("qwen3-14b", smoke=True).with_(kv_cache_dtype="int8",
                                                    remat=False)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    tok1 = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0,
                              cfg.vocab_size)
    cache = T.init_cache(cfg, 2, 24)
    leaves = jax.tree_util.tree_leaves(cache)
    assert any(l.dtype == jnp.int8 for l in leaves)
    out = T.prefill(params, cfg, toks, cache)
    step = T.decode_step(params, cfg, tok1, out.cache)
    ref = T.forward(params, cfg, jnp.concatenate([toks, tok1], 1))
    a = np.asarray(step.logits[:, 0], np.float32)
    b = np.asarray(ref.logits[:, -1], np.float32)
    rel = np.abs(a - b).max() / np.abs(b).max()
    assert rel < 0.05, rel


def test_bf16_cache_unchanged_default():
    cfg = get_config("qwen3-14b", smoke=True)
    cache = T.init_cache(cfg, 2, 16)
    leaves = jax.tree_util.tree_leaves(cache)
    assert not any(l.dtype == jnp.int8 for l in leaves)
