"""SLO engine: spec round-trip/validation, burn-rate math at the
documented thresholds (monotone budget, multi-window firing, hysteresis
clearing), the seeded eclipse+fault end-to-end scenario (a p99_ttft
page fires deterministically, floors the orbit mode at conserve, and
clears after recovery), the storm-ladder / autoscaler control coupling,
and the Prometheus / SLO_report exporters."""
import json

import pytest

from repro.launch.route import vision_fleet_spec
from repro.obs import (REASON_CODES, Alert, SLOObjective, SLOSpec,
                       export_slo_report, prometheus_text, slo_report)
from repro.orbit import Autoscaler, OrbitSpec, PhaseSpec, ScalingPolicy
from repro.serving import FaultSpec, FleetSpec, PoolSpec


def cost_spec(**kw):
    return FleetSpec(
        pools=[PoolSpec("board", ("mpsoc_dpu",), capacity=1,
                        max_window=4, max_wait_s=0.0)],
        workload="ursonet", **kw)


def avail_spec(**kw):
    """availability=0.9375 -> budget 1/16, exactly representable in
    binary so the threshold tests can hit page_burn=10.0 dead on."""
    base = dict(objectives=[SLOObjective("realtime-tracking",
                                         availability=0.9375)],
                fast_window_s=1.0, slow_window_s=5.0,
                page_burn=10.0, warn_burn=2.0, clear_frac=0.5,
                min_events=4)
    base.update(kw)
    return SLOSpec(**base)


def attach(spec_kw=None, **slo_kw):
    client = cost_spec(**(spec_kw or {})).build()
    engine = avail_spec(**slo_kw).attach(client)
    return client, engine


def feed(client, t, n_good=0, n_bad=0, slo_class="realtime-tracking"):
    """Push synthetic completions into the SLI registry at virtual t."""
    slis = client.router.telemetry.slis
    for _ in range(n_good):
        slis.observe_completion(t, slo_class, "board", 0.01)
    for _ in range(n_bad):
        slis.observe_completion(t, slo_class, "board", 0.5, violated=True)


# ---------------------------------------------------------------------------
# spec round-trip / validation
# ---------------------------------------------------------------------------
def test_slospec_json_round_trip():
    spec = SLOSpec(objectives=[
        SLOObjective("downlink-critical", p99_ttft_s=0.05, p99_itl_s=0.01),
        SLOObjective("background-science", p99_e2e_s=3.0,
                     availability=0.999)],
        fast_window_s=0.5, slow_window_s=2.5, page_burn=8.0)
    d = json.loads(json.dumps(spec.to_dict()))
    assert SLOSpec.from_dict(d) == spec


def test_slospec_from_dict_rejects_unknown_keys():
    d = avail_spec().to_dict()
    with pytest.raises(ValueError, match="unknown key.*bogus"):
        SLOSpec.from_dict({**d, "bogus": 1})
    with pytest.raises(ValueError, match="unknown key.*p99_ttft"):
        SLOObjective.from_dict({"slo_class": "a", "p99_ttft": 0.1})


@pytest.mark.parametrize("mutate, match", [
    (dict(objectives=[]), "at least one"),
    (dict(objectives=[SLOObjective("a", p99_ttft_s=0.1),
                      SLOObjective("a", p99_e2e_s=1.0)]), "duplicate"),
    (dict(objectives=[SLOObjective("a")]), "no bound"),
    (dict(objectives=[SLOObjective("a", p99_ttft_s=-1.0)]), "must be > 0"),
    (dict(objectives=[SLOObjective("a", availability=1.0)]),
     "zero error budget"),
    (dict(fast_window_s=5.0, slow_window_s=1.0), "fast_window_s"),
    (dict(warn_burn=20.0), "warn_burn"),
    (dict(clear_frac=0.0), "clear_frac"),
    (dict(min_events=0), "min_events"),
])
def test_slospec_validate_rejects(mutate, match):
    with pytest.raises(ValueError, match=match):
        avail_spec(**mutate).validate()


def test_fleetspec_round_trip_carries_slo_and_build_attaches():
    spec = cost_spec()
    spec.slo = avail_spec()
    d = json.loads(json.dumps(spec.to_dict()))
    spec2 = FleetSpec.from_dict(d)
    assert spec2.slo == spec.slo
    client = spec2.build()
    assert client.slo_engine is not None
    assert client.slo_engine.spec == spec.slo
    with pytest.raises(ValueError, match="already attached"):
        avail_spec().attach(client)


def test_reason_codes_are_the_stable_contract():
    spec = SLOSpec(objectives=[SLOObjective(
        "a", p99_ttft_s=0.1, p99_itl_s=0.01, p99_e2e_s=1.0,
        availability=0.99)])
    client = cost_spec().build()
    engine = spec.attach(client)
    assert {tr.reason for tr in engine.trackers} == set(REASON_CODES)


# ---------------------------------------------------------------------------
# burn-rate math at the documented thresholds
# ---------------------------------------------------------------------------
def test_page_fires_exactly_at_the_burn_threshold():
    # bad_frac 10/16 / budget 1/16 = burn 10.0 == page_burn: fires (>=)
    client, engine = attach()
    bus = client.router.telemetry.alerts
    feed(client, 0.9, n_good=6, n_bad=10)
    engine.step(1.0)
    assert bus.is_firing("availability_burn:realtime-tracking:page")
    assert bus.is_firing("availability_burn:realtime-tracking:warn")
    assert bus.pages_fired == 1 and bus.warns_fired == 1
    alert = bus.firing[0]
    assert alert.reason == "availability_burn"
    assert alert.burn_fast == pytest.approx(10.0)


def test_burn_just_below_page_threshold_warns_only():
    # bad_frac 9/16 / budget 1/16 -> burn 9.0 < 10: warn, never page
    client, engine = attach()
    bus = client.router.telemetry.alerts
    feed(client, 0.9, n_good=7, n_bad=9)
    engine.step(1.0)
    assert not bus.is_firing("availability_burn:realtime-tracking:page")
    assert bus.is_firing("availability_burn:realtime-tracking:warn")


def test_min_events_guard_blocks_thin_evidence():
    # 3 bad events is burn 20 but under min_events=4: no alert
    client, engine = attach()
    feed(client, 0.9, n_bad=3)
    engine.step(1.0)
    assert client.router.telemetry.alerts.firing_count == 0
    feed(client, 0.95, n_bad=1)          # 4th event crosses the guard
    engine.step(1.0)
    assert client.router.telemetry.alerts.firing_count == 2


def test_both_windows_must_burn_for_the_alert_to_fire():
    # a fast-window spike diluted across the slow window must not page:
    # 5 bad in the last second, but 200 good spread over the slow window
    client, engine = attach()
    bus = client.router.telemetry.alerts
    for k in range(200):
        feed(client, 0.02 * k, n_good=1)  # t in [0, 4.0)
    feed(client, 4.4, n_bad=5)
    engine.step(4.5)
    # burn_fast clears the page bar but burn_slow = (5/205)*16 ~ 0.4
    assert not bus.is_firing("availability_burn:realtime-tracking:page")
    assert bus.firing_count == 0


def test_budget_consumption_is_monotone():
    import random
    client, engine = attach()
    tracker = engine.trackers[0]
    rng = random.Random(42)
    prev_bad, prev_total = 0, 0
    for k in range(200):
        t = 0.01 * k
        feed(client, t, n_good=int(rng.random() < 0.7),
             n_bad=int(rng.random() < 0.3))
        engine.step(t)
        assert tracker.bad >= prev_bad
        assert tracker.total >= prev_total
        assert 0.0 <= tracker.budget_remaining() <= 1.0
        prev_bad, prev_total = tracker.bad, tracker.total
    # an all-bad tail only ever shrinks the remaining budget
    prev_rem = tracker.budget_remaining()
    for k in range(20):
        feed(client, 2.0 + 0.01 * k, n_bad=1)
        rem = tracker.budget_remaining()
        assert rem <= prev_rem
        prev_rem = rem


def test_alert_clears_with_hysteresis_and_never_flaps():
    client, engine = attach()
    bus = client.router.telemetry.alerts
    feed(client, 0.9, n_good=6, n_bad=10)
    engine.step(1.0)                      # burn 10: page + warn fire
    assert bus.firing_count == 2
    engine.step(1.5)                      # nothing changed: still firing
    assert bus.firing_count == 2 and bus.pages_fired == 1
    # 25 good events dilute the slow window to burn 10/41/(1/16) ~ 3.9
    # and empty the fast window of bad events; the page clears
    # (3.9 < 10*0.5) but the warn holds (3.9 >= 2*0.5) — hysteresis is
    # per-severity, not a shared cliff
    feed(client, 2.2, n_good=25)
    engine.step(2.3)
    assert not bus.is_firing("availability_burn:realtime-tracking:page")
    assert bus.is_firing("availability_burn:realtime-tracking:warn")
    # once every event ages out of the slow window the warn clears too
    engine.step(7.5)
    assert bus.firing_count == 0
    # each alert fired exactly once across the whole episode: no flap
    assert bus.pages_fired == 1 and bus.warns_fired == 1
    assert bus.cleared == 2
    page = [a for a in bus.history if a.severity == "page"][0]
    assert page.t_cleared == pytest.approx(2.3)


def test_drops_burn_latency_and_availability_budgets():
    spec = SLOSpec(objectives=[SLOObjective(
        "realtime-tracking", p99_ttft_s=0.1, availability=0.95)],
        min_events=1)
    client = cost_spec().build()
    engine = spec.attach(client)
    slis = client.router.telemetry.slis
    slis.observe_drop(0.5, "realtime-tracking", "board")
    engine.step(0.6)
    # a dropped request never delivered a first token: worst possible
    # outcome for both the latency objective and availability
    for tracker in engine.trackers:
        assert tracker.bad == 1


# ---------------------------------------------------------------------------
# end-to-end: eclipse+fault scenario (the acceptance criterion)
# ---------------------------------------------------------------------------
def _eclipse_fault_run():
    """Seeded, fully virtual: a pool fault under open-loop realtime
    traffic starves TTFT, the p99_ttft page fires, floors the mode at
    conserve on a full battery, and clears once the backlog drains."""
    spec = vision_fleet_spec(
        faults=[FaultSpec("board-a", at_s=0.25, duration_s=0.5)])
    spec.slo = SLOSpec(
        objectives=[SLOObjective("realtime-tracking", p99_ttft_s=0.08)],
        fast_window_s=0.2, slow_window_s=0.8,
        page_burn=5.0, warn_burn=2.0, min_events=3)
    client = spec.build()
    # huge sunlit bucket: any conserve transition is attributable to the
    # alert, never to the battery
    OrbitSpec(phases=[PhaseSpec("sunlit", 10.0, 100.0)],
              bucket_j=1e6, initial_frac=1.0).attach(client)
    n, rate = 90, 120.0
    submitted = 0
    paging_seen = []                      # (t, mode, reasons) while paging
    while (submitted < n or client.outstanding or client.pending_faults):
        client.advance()
        while submitted < n and client.now >= submitted / rate:
            client.submit(slo="realtime-tracking",
                          arrival=submitted / rate)
            submitted += 1
        client.pump()
        tel = client.router.telemetry
        if tel.alerts.paging:
            paging_seen.append((
                round(client.now, 6), client.controller.mode,
                tuple(sorted(a["reason"] for a in
                             tel.alerts.snapshot()["firing"]))))
        if client.now > 30.0:
            raise RuntimeError("scenario failed to drain")
    # idle out the slow window so every alert ages out and clears
    end = client.now + 1.0
    while client.now < end:
        client.step()
    return client, paging_seen


def test_eclipse_fault_scenario_pages_floors_mode_and_clears():
    client, paging_seen = _eclipse_fault_run()
    bus = client.router.telemetry.alerts
    # the page fired, with the stable reason code, and was visible in
    # snapshot()["alerts"] while firing
    assert paging_seen, "p99_ttft page alert never fired"
    assert any("p99_ttft_burn" in reasons for _, _, reasons in paging_seen)
    pages = [a for a in bus.history
             if a.severity == "page" and a.reason == "p99_ttft_burn"]
    assert pages and pages[0].slo_class == "realtime-tracking"
    # while paging the orbit mode was floored at conserve — on a battery
    # that never left nominal territory
    assert {mode for _, mode, _ in paging_seen} == {"conserve"}
    # recovery: every alert cleared and the mode returned to nominal
    assert bus.firing_count == 0
    assert all(a.t_cleared is not None for a in bus.history)
    assert client.controller.mode == "nominal"
    modes = [m for _, m in client.controller.transitions]
    assert modes[-1] == "nominal" and "conserve" in modes
    # the conserve window brackets the fault, not the whole run
    assert client.telemetry["alerts"]["pages_fired"] >= 1


def test_eclipse_fault_scenario_is_deterministic():
    c1, seen1 = _eclipse_fault_run()
    c2, seen2 = _eclipse_fault_run()
    assert seen1 == seen2
    h1 = [a.to_dict() for a in c1.router.telemetry.alerts.history]
    h2 = [a.to_dict() for a in c2.router.telemetry.alerts.history]
    assert h1 == h2
    assert c1.controller.transitions == c2.controller.transitions
    assert c1.telemetry["slis"] == c2.telemetry["slis"]


# ---------------------------------------------------------------------------
# control coupling: storm ladder + autoscaler hold
# ---------------------------------------------------------------------------
def test_fired_page_joins_the_storm_ladder():
    client = cost_spec().build()
    ctrl = OrbitSpec(phases=[PhaseSpec("sunlit", 10.0, 100.0)],
                     bucket_j=1e6, initial_frac=1.0,
                     storm_events=1, storm_decay=0.5).attach(client)
    bus = client.router.telemetry.alerts
    bus.fire(Alert("p99_ttft_burn", "realtime-tracking", "page",
                   0.1, 9.9, 9.9, 5.0))
    client.advance()
    # pages_fired is a hardening event: the ladder latches storm AND the
    # firing page itself floors the mode
    assert ctrl.storm_pressure >= 1.0
    assert ctrl.mode == "conserve"
    bus.clear("p99_ttft_burn:realtime-tracking:page", client.now)
    for _ in range(12):                  # pressure decays 0.5x per tick
        client.advance()
    assert not ctrl.storm
    assert ctrl.mode == "nominal"
    assert ctrl.report()["alerts"]["pages_fired"] == 1


def test_firing_alert_holds_autoscaler_scale_down():
    client = cost_spec().build()
    client.set_capacity("board", 2)
    policy = ScalingPolicy(template="board", grow="capacity",
                           min_capacity=1, max_capacity=4, queue_high=8,
                           cooldown_s=0.0)
    scaler = Autoscaler(policy, template_spec=client.spec.pools[0])
    # idle fleet, but an alert is firing: the shrink branch must hold
    act = scaler.step(client, now=1.0, mode="nominal",
                      hold_scale_down=True)
    assert act is None
    assert client.router.pools["board"].capacity == 2
    # alert cleared: the same idle state now shrinks
    act = scaler.step(client, now=2.0, mode="nominal")
    assert act is not None and act["op"] == "set_capacity"
    assert client.router.pools["board"].capacity == 1


def test_controller_passes_hold_while_alert_fires():
    spec = cost_spec()
    client = spec.build()
    client.set_capacity("board", 2)
    policy = ScalingPolicy(template="board", grow="capacity",
                           min_capacity=1, max_capacity=4, queue_high=8,
                           cooldown_s=0.0)
    ctrl = OrbitSpec(phases=[PhaseSpec("sunlit", 10.0, 100.0)],
                     bucket_j=1e6, initial_frac=1.0,
                     scaling=policy).attach(client)
    bus = client.router.telemetry.alerts
    bus.fire(Alert("p99_e2e_burn", "background-science", "warn",
                   0.0, 3.0, 3.0, 2.0))
    client.advance()
    assert client.router.pools["board"].capacity == 2   # held
    bus.clear("p99_e2e_burn:background-science:warn", client.now)
    client.advance()
    assert client.router.pools["board"].capacity == 1   # released


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_prometheus_text_dump(tmp_path):
    client, engine = attach()
    client.submit(slo="realtime-tracking")
    client.drain()
    text = prometheus_text(client)
    assert "# TYPE repro_fleet_events_total counter" in text
    assert 'repro_fleet_events_total{event="completed"} 1' in text
    assert 'repro_pool_completed_total{pool="board"} 1' in text
    assert 'repro_sli_ttft_seconds{scope="fleet",quantile="p99"}' in text
    assert "repro_slo_budget_remaining" in text
    assert "repro_alerts_firing" in text


def test_slo_report_export(tmp_path):
    client, engine = attach()
    client.submit(slo="realtime-tracking")
    client.drain()
    path = tmp_path / "SLO_report.json"
    report = export_slo_report(client, str(path))
    data = json.loads(path.read_text())
    assert data == json.loads(json.dumps(report))
    objectives = data["slo"]["objectives"]
    assert objectives[0]["slo_class"] == "realtime-tracking"
    assert objectives[0]["budget_remaining"] == 1.0
    # the embedded spec round-trips back into an equal SLOSpec
    assert SLOSpec.from_dict(data["slo"]["spec"]) == engine.spec
    assert data["telemetry"]["slis"]["fleet"]["completed"] == 1
    assert "timeseries" in data


def test_slo_report_without_engine_is_still_valid():
    client = cost_spec().build()
    client.submit(slo="bulk-reprocess")
    client.drain()
    report = slo_report(client)
    assert report["slo"] is None
    json.dumps(report)
