"""fleetlint fixture: virtual-clock purity violations (VCP001/VCP002)."""
import random
import time
from datetime import datetime

import numpy as np


def stamp():
    return time.time()                       # VCP001


def measure():
    return time.perf_counter()               # VCP001


def when():
    return datetime.now()                    # VCP001


def jitter():
    return random.random()                   # VCP002 (module-global RNG)


def rng():
    return np.random.default_rng()           # VCP002 (unseeded)


def legacy_draw():
    return np.random.uniform()               # VCP002 (global state)
