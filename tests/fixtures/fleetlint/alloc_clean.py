"""fleetlint fixture: clean twin of alloc_bad — sanctioned methods only."""


def take_block(alloc):
    return alloc.alloc(1)[0]                 # sanctioned allocation


def quarantine_block(engine, blk):
    engine.alloc.quarantine(blk)             # sanctioned (keeps the books)


def scrub_budget(engine):
    return len(engine.alloc.quarantined)     # reads are fine


def release_ref(shared, blk):
    shared.release(blk)                      # sanctioned refcount decrement


def forget_digest(digests, blk):
    digests.forget(blk)                      # sanctioned digest retirement


def free_worklist(freelist):
    freelist.free.pop()                      # '.free' on a non-allocator
