"""fleetlint fixture: exception-swallowing violations (EXC001/EXC002)."""


def swallow_everything(fn):
    try:
        return fn()
    except:                                  # EXC001 (bare)
        return None


def swallow_broad(fn):
    try:
        return fn()
    except Exception:                        # EXC002 (reason discarded)
        return None
