"""fleetlint fixture: clean twin of jit_bad — same shapes, no hazards."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def device_convert(x):
    return x.astype(jnp.bool_)               # stays on device


@jax.jit
def device_numpy(x):
    return jnp.sum(x)                        # jnp, not host numpy


@functools.partial(jax.jit, static_argnames=("flag",))
def static_branch(x, y, flag=False):
    if flag:                                 # static arg: trace-time branch
        y = y * 2
    return jnp.where(x > 0, y, -y)           # traced select on device


@functools.partial(jax.jit, static_argnames=("width",))
def hashable_static(x, width=128):           # int static: hashable, cached
    b, d = x.shape
    if d > width:                            # shape-derived: static too
        x = x[:, :width]
    return x


def make_step(offset: int):
    step = jax.jit(lambda x: x + offset)     # captures an immutable int
    return step
