"""fleetlint fixture: clean twin of exc_bad — reasons survive."""


class TransientError(RuntimeError):
    pass


def narrow(fn):
    try:
        return fn()
    except TransientError:                   # specific type: reason intact
        return None


def accounted(fn, counters):
    try:
        return fn()
    except Exception as e:                   # broad but the reason is kept
        counters.record_drop(reason=type(e).__name__)
        return None


def rewrapped(fn):
    try:
        return fn()
    except Exception as e:                   # broad but re-raised
        raise RuntimeError("fixture") from e
