"""fleetlint fixture: clean twin of clock_bad — virtual clock + seeded RNG."""
import random

import numpy as np


def stamp(clock):
    return clock.now()                       # virtual clock injection


def jitter(seed: int):
    return random.Random(seed).random()      # seeded instance RNG


def rng(seed: int):
    return np.random.default_rng(seed)       # seeded generator
