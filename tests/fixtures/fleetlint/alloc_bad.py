"""fleetlint fixture: allocator-accounting violations (ALC001)."""


def steal_block(alloc):
    return alloc.free.pop()                  # ALC001 (bypasses accounting)


def hide_block(engine, blk):
    engine.alloc.quarantined.add(blk)        # ALC001 (no on_release hook)


def forge_refcount(shared, blk):
    shared._refs[blk] = 99                   # ALC001 (private state)


def drop_digest(digests, blk):
    del digests._sums[blk]                   # ALC001 (private state)
