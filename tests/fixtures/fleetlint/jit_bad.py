"""fleetlint fixture: jit-boundary hazards (JIT001-JIT005)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def host_convert(x):
    return bool(x)                           # JIT001


@jax.jit
def host_numpy(x):
    return np.sum(x)                         # JIT002


@functools.partial(jax.jit, static_argnames=("flag",))
def traced_branch(x, y, flag=False):
    if flag:                                 # static — fine
        y = y * 2
    if x > 0:                                # JIT003 (traced branch)
        return y
    return -y


@functools.partial(jax.jit, static_argnames=("cfg",))
def unhashable_static(x, cfg={}):            # JIT004
    return x


def make_step():
    table = []                               # mutated after trace -> stale
    step = jax.jit(lambda x: x + len(table))  # JIT005
    return step
