"""Sharding-rule validation WITHOUT devices: every param/cache/data spec of
every full-size architecture must divide evenly on both production meshes.
This is the cheap static proof behind the compile-level dry-run."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shard
from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.configs.base import MULTI_POD, SINGLE_POD
from repro.launch import specs as S


def _axis_sizes(mesh_cfg):
    return dict(zip(mesh_cfg.axes, mesh_cfg.shape))


def _check_divisible(spec_tree, shape_tree, mesh_cfg, ctx):
    sizes = _axis_sizes(mesh_cfg)
    leaves_spec = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    leaves_shape = jax.tree_util.tree_leaves(shape_tree)
    assert len(leaves_spec) == len(leaves_shape), ctx
    for sp, leaf in zip(leaves_spec, leaves_shape):
        for dim, axes in zip(leaf.shape, tuple(sp)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, (ctx, leaf.shape, tuple(sp), dim, total)


@pytest.mark.parametrize("mesh_cfg", [SINGLE_POD, MULTI_POD],
                         ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_divide(arch, mesh_cfg):
    cfg = get_config(arch)
    tree = S.param_structs(cfg, mesh_cfg.tp)
    specs = shard.param_specs(cfg, tree, mesh_cfg)
    _check_divisible(specs, tree, mesh_cfg, arch)


@pytest.mark.parametrize("mesh_cfg", [SINGLE_POD, MULTI_POD],
                         ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_cache_and_data_specs_divide(arch, mesh_cfg):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.supports_long_context():
            continue
        data = S.batch_specs(cfg, shape)
        dspec = shard.data_specs(cfg, shape, mesh_cfg)
        _check_divisible(dspec, data, mesh_cfg, (arch, shape.name, "data"))
        if shape.kind == "decode":
            cache = S.cache_structs(cfg, shape, mesh_cfg.tp)
            cspec = shard.cache_specs(cfg, cache, shape, mesh_cfg)
            _check_divisible(cspec, cache, mesh_cfg,
                             (arch, shape.name, "cache"))


def test_batch_axes_fallback():
    from repro.configs.base import ShapeConfig
    # batch 1 cannot shard -> replicated
    assert shard.batch_axes(1, SINGLE_POD) is None
    # batch 128 on multi-pod: 2*16=32 divides -> (pod, data)
    assert shard.batch_axes(128, MULTI_POD) == ("pod", "data")
    # batch 8: only pod (2) divides
    assert shard.batch_axes(8, MULTI_POD) == ("pod",)
