"""Serving facade: FleetSpec round-trip, streaming order/completeness/
incrementality, SLO violation + rejection surfacing, OutOfBlocks
deferral-then-completion through the facade, and backend-invariant
greedy outputs."""
import json

import numpy as np
import jax
import pytest

from repro.models import transformer as T
from repro.serving import (FaultSpec, FleetSpec, LMWork, PoolSpec,
                           SamplingParams, SLOClass, open_loop)

from conftest import tiny_dense

PROMPT_LEN, MAX_NEW = 8, 6


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def lm_spec(backend="engine", **pool_kw):
    kw = dict(capacity=1, max_window=4, max_wait_s=0.0, max_slots=3,
              prompt_len=PROMPT_LEN, max_new=MAX_NEW)
    kw.update(pool_kw)
    return FleetSpec(pools=[PoolSpec("lm", ("tpu_v5e_bf16",),
                                     backend=backend, **kw)],
                     workload="transformer", seq_len=PROMPT_LEN)


def prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN))
                         ).astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------------------
# FleetSpec round-trip
# ---------------------------------------------------------------------------
def test_fleet_spec_dict_round_trip():
    spec = FleetSpec(
        pools=[PoolSpec("board-a", ("mpsoc_dpu", "myriadx_vpu"),
                        capacity=2, max_window=4),
               PoolSpec("lm", ("tpu_v5e_bf16",), backend="engine",
                        max_slots=2, prompt_len=8, max_new=6,
                        num_blocks=12, plan="mpai", plan_split=1)],
        workload="ursonet",
        accuracy_penalty={"mpsoc_dpu": 0.05},
        cut_candidates=[1, 2],
        slos=[dict(name="custom", max_latency_s=0.5, priority=1)],
        faults=[FaultSpec("board-a", at_s=1.0, duration_s=2.0,
                          lost_profiles=("mpsoc_dpu",))])
    d = spec.to_dict()
    restored = FleetSpec.from_dict(json.loads(json.dumps(d)))
    assert restored.to_dict() == d          # dict -> spec -> dict
    assert restored.pools[1].profiles == ("tpu_v5e_bf16",)
    assert restored.faults[0].lost_profiles == ("mpsoc_dpu",)


def test_pool_spec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        PoolSpec("x", ("tpu_v5e_bf16",), backend="gpu")


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------
def test_streaming_order_and_completeness(model):
    """stream() yields exactly the request's final output, in order,
    for every request in a shared batch."""
    client = lm_spec().build(model=model)
    handles = [client.submit(p, slo="offline", max_new=1 + i)
               for i, p in enumerate(prompts(3))]
    streamed = {h.rid: list(h.stream()) for h in handles}
    client.drain()
    engine = client.engines["lm"]
    for h in handles:
        out = engine.done[h.rid].output
        assert streamed[h.rid] == out.tolist()
        np.testing.assert_array_equal(h.result().tokens, out)
        assert h.telemetry["tokens"] == out.shape[0]


def test_streaming_is_incremental_across_decode_steps(model):
    """Tokens carry strictly increasing engine decode-step stamps, and a
    short request's tokens land *between* a long neighbor's — per-step
    delivery from live slots, not an at-completion dump."""
    client = lm_spec(max_slots=2).build(model=model)
    p = prompts(2, seed=1)
    long = client.submit(p[0], slo="offline", max_new=MAX_NEW)
    short = client.submit(p[1], slo="offline", max_new=2)
    client.drain()
    ls, ss = long.token_steps, short.token_steps
    assert None not in ls and None not in ss     # engine-stamped
    assert len(ls) == MAX_NEW and len(ss) == 2
    assert all(a < b for a, b in zip(ls[1:], ls[2:]))  # one per step
    # the short request finished while the long one was mid-decode
    assert ss[-1] < ls[-1]


def test_windowed_backend_streams_at_completion(model):
    """Hook-less backends still satisfy stream(): same tokens, delivered
    with completion-time (None) stamps."""
    client = lm_spec(backend="windowed").build(model=model)
    h = client.submit(prompts(1)[0], slo="offline", max_new=4)
    toks = list(h.stream())
    assert toks == client.engines["lm"].done[h.rid].output.tolist()
    assert h.token_steps == [None] * 4


def test_greedy_outputs_backend_invariant(model):
    """The same greedy workload routed through an engine pool and a
    windowed pool produces identical tokens (only scheduling differs)."""
    outs = {}
    for backend in ("engine", "windowed"):
        client = lm_spec(backend=backend).build(model=model)
        handles = [client.submit(p, slo="offline", max_new=3 + i)
                   for i, p in enumerate(prompts(3, seed=2))]
        client.drain()
        outs[backend] = [h.result().tokens.tolist() for h in handles]
    assert outs["engine"] == outs["windowed"]


def test_sampling_params_thread_through_facade(model):
    sp = SamplingParams(temperature=1.0, top_k=8, seed=7)
    runs = []
    for _ in range(2):
        client = lm_spec().build(model=model)
        h = client.submit(prompts(1, seed=3)[0], slo="offline",
                          max_new=MAX_NEW, sampling=sp)
        runs.append(h.result().tokens.tolist())
    assert runs[0] == runs[1]              # seeded -> reproducible


# ---------------------------------------------------------------------------
# SLO violations / rejections surfaced on the handle
# ---------------------------------------------------------------------------
def _vision_spec(**kw):
    return FleetSpec(
        pools=[PoolSpec("board", ("mpsoc_dpu",), capacity=1,
                        max_window=4, max_wait_s=0.0)],
        workload="ursonet", **kw)


def test_slo_violation_surfaced_on_handle():
    """Batch-size pricing pushes a full window past a deadline sized for
    a lone request: admission passes, completion records the miss."""
    client = _vision_spec().build()
    nominal = client.router.frontier[0].latency_s
    tight = SLOClass("tight", max_latency_s=1.5 * nominal)
    handles = [client.submit(slo=tight) for _ in range(4)]
    client.drain()
    results = [h.result() for h in handles]
    assert all(r.latency_s is not None for r in results)
    assert any(r.violated for r in results)
    tel = client.telemetry
    assert tel["violations"] == sum(r.violated for r in results)
    assert all(h.telemetry["violated"] == r.violated
               for h, r in zip(handles, results))


def test_rejection_surfaced_on_handle():
    client = _vision_spec().build()
    h = client.submit(slo=SLOClass("impossible", max_latency_s=1e-9))
    assert not h.admitted and h.done
    r = h.result()
    assert not r.admitted and r.latency_s is None
    assert client.telemetry["rejected"] == 1


def test_fault_drop_surfaced_as_violation():
    """Sole pool dies permanently mid-flight: the displaced request is
    dropped, counted as a violation, and the handle reports it."""
    import math
    client = _vision_spec(
        faults=[FaultSpec("board", at_s=0.001,
                          duration_s=math.inf)]).build()
    h = client.submit(slo="bulk-reprocess")
    client.drain()
    r = h.result()
    assert r.dropped and r.violated
    snap = client.telemetry
    assert snap["dropped"] == 1 and snap["violations"] == 1


def test_oversized_max_new_fails_fast_with_actionable_error(model):
    client = lm_spec().build(model=model)
    with pytest.raises(ValueError, match="max_new"):
        client.submit(prompts(1)[0], slo="offline", max_new=100)
    assert client.telemetry["admitted"] == 0     # rejected before admission


def test_failover_restream_does_not_duplicate_tokens(model):
    """An SEU mid-decode evicts the virtually-in-flight batch; the
    re-dispatched request must end with exactly max_new tokens, whether
    it re-lands on the same engine (finished output handed back) or a
    survivor pool (stream restarts)."""
    import math
    spec = lm_spec()
    spec.pools.append(PoolSpec("lm-b", ("tpu_v5e_bf16",),
                               backend="engine", capacity=1, max_window=4,
                               max_wait_s=0.0, max_slots=3,
                               prompt_len=PROMPT_LEN, max_new=MAX_NEW))
    # the batch executes (and streams) on the first tick but stays
    # virtually in-flight until its measured latency elapses; the fault
    # lands inside that window and evicts it
    spec.faults = [FaultSpec("lm", at_s=0.003, duration_s=math.inf)]
    client = spec.build(model=model)
    h = client.submit(prompts(1, seed=6)[0], slo="offline",
                      max_new=MAX_NEW)
    client.drain()
    r = h.result()
    assert h.telemetry["rerouted"] >= 1          # failover happened
    assert not r.dropped
    assert r.tokens.shape == (MAX_NEW,)          # no duplicated stream
def test_out_of_blocks_deferral_then_completion(model):
    """KV pool sized for one max-length request: the engine admits
    one-at-a-time, deferrals show up as pool backpressure telemetry,
    and every request still completes with its exact max_new."""
    spec = lm_spec(max_slots=3, block_size=4,
                   num_blocks=-(-(PROMPT_LEN + max(MAX_NEW, 2)) // 4))
    client = spec.build(model=model)
    handles = [client.submit(p, slo="offline", max_new=4)
               for p in prompts(3, seed=4)]
    client.drain()
    for h in handles:
        assert h.result().tokens.shape == (4,)
    pool = client.telemetry["pools"]["lm"]
    assert pool["deferrals"] >= 1          # backpressure was exercised
    engine = client.engines["lm"]
    assert engine.alloc.available == engine.alloc.num_blocks


# ---------------------------------------------------------------------------
# decode-only tokens/s telemetry
# ---------------------------------------------------------------------------
def test_decode_only_tokens_per_s_excludes_prefill(model):
    client = lm_spec().build(model=model)
    handles = [client.submit(p, slo="offline", max_new=4)
               for p in prompts(3, seed=5)]
    client.drain()
    pool = client.telemetry["pools"]["lm"]
    total = sum(len(h.tokens) for h in handles)
    assert pool["tokens_generated"] == total == 12
    # one token per request comes from the admission prefill, not decode
    assert pool["decode_tokens"] == total - 3
    assert 0 < pool["decode_s"] <= pool["busy_s"]
    assert pool["decode_tokens_per_s"] > pool["tokens_per_s"]


# ---------------------------------------------------------------------------
# open-loop driver (moved here from launch/route.py)
# ---------------------------------------------------------------------------
def test_open_loop_drains_and_returns_handles(model):
    client = lm_spec().build(model=model)
    relaxed = SLOClass("lm-offline", max_latency_s=600.0)

    def payload(rng):
        return LMWork(rng.integers(0, 256, 4).astype(np.int32),
                      max_new=int(rng.integers(1, MAX_NEW + 1)))

    handles = open_loop(client, [relaxed], [1.0], rate_hz=200.0,
                        n_requests=8, seed=0, dt=0.01,
                        payload_fn=payload)
    assert len(handles) == 8
    assert client.outstanding == 0
    assert all(h.done for h in handles)
    snap = client.telemetry
    assert snap["completed"] == snap["admitted"] == 8
    assert snap["pools"]["lm"]["tokens_generated"] == sum(
        len(h.tokens) for h in handles) > 0


# ---------------------------------------------------------------------------
# empty / oversized prompts fail fast at the front door
# ---------------------------------------------------------------------------
def test_empty_prompt_fails_fast_with_actionable_error(model):
    client = lm_spec().build(model=model)
    with pytest.raises(ValueError, match="empty prompt"):
        client.submit(np.zeros((0,), np.int32), max_new=2)
    # nothing was counted admitted and the fleet still serves
    assert client.telemetry["admitted"] == 0
    h = client.submit(prompts(1)[0], max_new=2)
    assert h.result().tokens.shape == (2,)


def test_oversized_prompt_fails_fast_with_actionable_error(model):
    client = lm_spec().build(model=model)
    with pytest.raises(ValueError, match="max_prompt_len"):
        client.submit(np.arange(100, dtype=np.int32), max_new=2)


# ---------------------------------------------------------------------------
# chunked paged prefill + disaggregation through the facade
# ---------------------------------------------------------------------------
def test_long_prompt_admits_through_facade(model):
    """max_prompt_len lifts the prompt_len bucket: a 3x-bucket prompt
    streams through the same front door as any other request."""
    client = lm_spec(max_prompt_len=4 * PROMPT_LEN).build(model=model)
    prompt = np.random.default_rng(21).integers(
        0, 256, 3 * PROMPT_LEN + 1).astype(np.int32)
    h = client.submit(prompt, max_new=4)
    assert list(h.stream()) == list(h.result().tokens)
    assert len(h.tokens) == 4
    t = h.telemetry
    assert t["prefill_pool"] is None        # unified pool: no split
    assert t["decode_pool"] == "lm"


def _disagg_spec(**kw):
    return lm_spec(max_prompt_len=4 * PROMPT_LEN,
                   prefill_backend="engine", **kw)


def test_disaggregated_pool_serves_and_stamps_stage_pools(model):
    client = _disagg_spec().build(model=model)
    rng = np.random.default_rng(22)
    handles = [client.submit(rng.integers(0, 256, n).astype(np.int32),
                             max_new=3)
               for n in (5, 2 * PROMPT_LEN + 3, 3 * PROMPT_LEN)]
    for h in handles:
        r = h.result()
        assert list(r.tokens) == h.tokens and len(h.tokens) == 3
        assert h.telemetry["prefill_pool"] == "lm.prefill"
        assert h.telemetry["decode_pool"] == "lm"


def test_disaggregated_pool_charges_each_stage_separately(model):
    client = _disagg_spec().build(model=model)
    prompt = np.random.default_rng(23).integers(
        0, 256, 2 * PROMPT_LEN).astype(np.int32)
    client.submit(prompt, max_new=4).result()
    pools = client.telemetry["pools"]
    assert set(pools) == {"lm", "lm.prefill"}
    pre, dec = pools["lm.prefill"], pools["lm"]
    # prompt tokens + their energy land on the prefill stage's pool;
    # decode tokens + theirs on the routed decode pool — no leakage
    assert pre["prefill_tokens"] > 0 and pre["energy_j"] > 0
    assert dec["prefill_tokens"] == 0
    assert dec["decode_tokens"] == 3 and dec["energy_j"] > 0
    assert pre["decode_tokens"] == 0
    # fleet-level energy is the sum over both stages (summaries round
    # to 4 decimals, so compare at that granularity)
    assert client.telemetry["energy_j"] == pytest.approx(
        pre["energy_j"] + dec["energy_j"], abs=2e-4)


def test_disaggregated_outputs_match_unified_pool(model):
    rng = np.random.default_rng(24)
    ps = [rng.integers(0, 256, n).astype(np.int32)
          for n in (4, PROMPT_LEN + 5, 3 * PROMPT_LEN)]
    outs = {}
    for kind, spec in (("unified",
                        lm_spec(max_prompt_len=4 * PROMPT_LEN)),
                       ("disagg", _disagg_spec())):
        client = spec.build(model=model)
        outs[kind] = [list(client.submit(p, max_new=4).result().tokens)
                      for p in ps]
    assert outs["unified"] == outs["disagg"]


def test_pool_spec_round_trips_coproc_fields():
    spec = _disagg_spec(prefill_plan="mpai", prefill_energy_scale=0.25,
                        prefill_chunk=16)
    restored = FleetSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert restored.to_dict() == spec.to_dict()
    p = restored.pools[0]
    assert (p.prefill_backend, p.prefill_plan, p.prefill_energy_scale,
            p.prefill_chunk, p.max_prompt_len) == (
        "engine", "mpai", 0.25, 16, 4 * PROMPT_LEN)


def test_pool_spec_rejects_prefill_backend_without_engine():
    with pytest.raises(ValueError, match="prefill_backend"):
        PoolSpec("p", ("x",), backend="windowed",
                 prefill_backend="engine")


def test_disaggregated_int8_prefill_plan_completes_streams(model):
    """The DPU-analogue running the int8 mpai plan: precision differs
    from the decode stage by design, so tokens are not gated against
    the unified pool — completeness and stage attribution are."""
    client = _disagg_spec(prefill_plan="mpai").build(model=model)
    rng = np.random.default_rng(25)
    for n in (5, 2 * PROMPT_LEN + 3, 3 * PROMPT_LEN):
        h = client.submit(rng.integers(0, 256, n).astype(np.int32),
                          max_new=4)
        r = h.result()
        assert len(r.tokens) == 4 and list(r.tokens) == h.tokens
        assert h.telemetry["prefill_pool"] == "lm.prefill"
    assert client.telemetry["pools"]["lm.prefill"]["energy_j"] > 0


def test_combined_prompt_and_max_new_overflow_fails_at_submit(model):
    """A padded prompt + max_new that individually pass the bucket
    checks but jointly overflow the KV table must fail at submit() —
    not blow up mid-batch inside the pool and take already-batched
    neighbors down with it."""
    client = lm_spec(max_prompt_len=4 * PROMPT_LEN).build(model=model)
    good = client.submit(prompts(1)[0], max_new=2)
    # prompt pads to 32; 32 + max_new 8 > max_len 38 is fine, but
    # 32 + 12 > 38 overflows even though 12 <= max_len - prompt_len
    with pytest.raises(ValueError, match="cannot fit"):
        client.submit(np.arange(1, 31, dtype=np.int32), max_new=12)
    assert len(good.result().tokens) == 2      # neighbor unharmed


def test_disaggregated_pool_retire_and_readd_continues_stage_history(
        model):
    """Retiring a disaggregated pool keeps both pools' counters as
    history; re-adding the same name splices onto them (cumulative
    energy stays monotone for the orbit bucket) instead of failing
    mid-mutation."""
    spec = _disagg_spec()
    spec.pools.append(PoolSpec("spare", ("tpu_v5e_bf16",),
                               backend="engine", max_slots=2,
                               prompt_len=PROMPT_LEN, max_new=MAX_NEW))
    client = spec.build(model=model)
    p = prompts(1, seed=30)[0]
    client.submit(p, max_new=3).result()
    e0 = client.telemetry["pools"]["lm.prefill"]["energy_j"]
    assert e0 > 0
    client.retire_pool("lm")
    client.step()              # drained pool is removed on a later step
    assert "lm" not in client.router.pools
    client.add_pool(spec.pools[0])               # same name, same stage
    client.submit(p, max_new=3).result()
    e1 = client.telemetry["pools"]["lm.prefill"]["energy_j"]
    assert e1 > e0                               # history continued


# ---------------------------------------------------------------------------
# radiation hardening: SEU injection -> detection -> exactly-once recovery
# ---------------------------------------------------------------------------
def _run_hardening(spec, model, n=4, seed=9):
    client = spec.build(model=model)
    handles = [client.submit(p, slo="offline", max_new=MAX_NEW)
               for p in prompts(n, seed=seed)]
    client.drain()
    return client, [tuple(h.tokens) for h in handles]


def test_fault_spec_round_trips_hardening_fields():
    spec = lm_spec(harden=True, watchdog_steps=4, scrub_blocks=1)
    spec.faults = [FaultSpec("lm", at_s=0.01, duration_s=0.5,
                             kind="slot_stall", slot=2),
                   FaultSpec("lm", at_s=0.02, kind="kv_bitflip", seed=11)]
    spec.retry = {"default": dict(max_attempts=3, backoff_s=0.01),
                  "offline": dict(max_attempts=2)}
    spec.watchdog_s = 1.5
    d = spec.to_dict()
    restored = FleetSpec.from_dict(json.loads(json.dumps(d)))
    assert restored.to_dict() == d
    assert restored.faults[0].kind == "slot_stall"
    assert restored.faults[0].slot == 2
    assert restored.faults[1].seed == 11
    assert restored.pools[0].harden
    assert restored.pools[0].watchdog_steps == 4
    with pytest.raises(ValueError, match="fault kind"):
        FaultSpec("lm", at_s=0.0, kind="cosmic_ray")


def test_hardened_no_faults_is_bit_identical_and_quiet(model):
    """Hardening on, no faults: same bits as hardening off, and every
    hardening counter stays zero — the layer is pure overhead-free
    observation until something actually upsets."""
    _, base = _run_hardening(lm_spec(), model)
    client, hard = _run_hardening(lm_spec(harden=True), model)
    assert hard == base
    pool = client.telemetry["pools"]["lm"]
    assert pool["bitflips_detected"] == 0
    assert pool["blocks_quarantined"] == 0
    assert pool["watchdog_trips"] == 0


def test_kv_bitflip_detected_quarantined_recovered_bit_exact(model):
    """A scheduled SEU flips one bit in a sealed KV block: the fused
    decode-path verify catches it the same step, the block quarantines
    with exact allocator accounting, and the evicted request replays to
    the same bits an unfaulted run produces."""
    _, base = _run_hardening(lm_spec(), model)
    spec = lm_spec()          # harden auto-enabled by the data-plane fault
    spec.faults = [FaultSpec("lm", at_s=0.001, kind="kv_bitflip", seed=3)]
    client, out = _run_hardening(spec, model)
    engine = client.engines["lm"]
    assert engine.harden                         # build() hardened it
    assert out == base                           # recovery is bit-exact
    pool = client.telemetry["pools"]["lm"]
    assert pool["bitflips_detected"] >= 1
    assert pool["blocks_quarantined"] >= 1
    # quarantined blocks stay out of service; everything else came home
    alloc = engine.alloc
    assert alloc.available + len(alloc.quarantined) == alloc.num_blocks
    snap = client.telemetry
    assert snap["completed"] == snap["admitted"]  # nobody dropped


def test_slot_stall_watchdog_evicts_and_replays_bit_exact(model):
    """A latched-up slot makes no decode progress: the engine watchdog
    trips after watchdog_steps, evicts, and replays elsewhere — final
    tokens bit-match the unfaulted run."""
    _, base = _run_hardening(lm_spec(), model)
    spec = lm_spec(watchdog_steps=3)
    spec.faults = [FaultSpec("lm", at_s=0.001, duration_s=0.5,
                             kind="slot_stall", slot=0)]
    client, out = _run_hardening(spec, model)
    assert out == base
    pool = client.telemetry["pools"]["lm"]
    assert pool["watchdog_trips"] >= 1


def test_handoff_loss_is_replayed_exactly_once(model):
    """A dropped prefill->decode handoff is re-requested at the seam;
    the replacement regenerates identical KV bits and the stream never
    sees a duplicated or missing token."""
    _, base = _run_hardening(_disagg_spec(), model)
    spec = _disagg_spec()
    spec.faults = [FaultSpec("lm", at_s=0.001, kind="handoff_loss")]
    client = spec.build(model=model)
    handles = [client.submit(p, slo="offline", max_new=MAX_NEW)
               for p in prompts(4, seed=9)]
    streamed = {h.rid: list(h.stream()) for h in handles}
    client.drain()
    assert [tuple(h.tokens) for h in handles] == base
    pool = client.telemetry["pools"]["lm"]
    assert pool["handoffs_replayed"] >= 1
    for h in handles:        # exactly-once delivery on the stream
        assert streamed[h.rid] == list(h.result().tokens)


def test_stream_cursor_survives_midstream_reroute(model):
    """stream() across a mid-decode failover: the re-served request's
    tokens arrive exactly once, in order (regression: the backfill
    cursor used to re-deliver the pre-fault prefix after a reroute)."""
    import math
    spec = lm_spec()
    spec.pools.append(PoolSpec("lm-b", ("tpu_v5e_bf16",),
                               backend="engine", capacity=1, max_window=4,
                               max_wait_s=0.0, max_slots=3,
                               prompt_len=PROMPT_LEN, max_new=MAX_NEW))
    spec.faults = [FaultSpec("lm", at_s=0.003, duration_s=math.inf)]
    client = spec.build(model=model)
    h = client.submit(prompts(1, seed=6)[0], slo="offline",
                      max_new=MAX_NEW)
    toks = list(h.stream())
    assert h.telemetry["rerouted"] >= 1          # the fault really hit
    assert toks == list(h.result().tokens)       # no dupes, no holes
    assert len(toks) == MAX_NEW


# ---------------------------------------------------------------------------
# fail-fast spec validation (fleetlint PR): bad specs die at build
# time with the field named, never deep inside engine assembly
# ---------------------------------------------------------------------------
def _cost_pool(**kw):
    base = dict(name="p", profiles=("cpu_bf16",), backend="costmodel")
    return PoolSpec(**{**base, **kw})


def test_pool_validate_rejects_unaligned_prefill_chunk():
    ps = _cost_pool(backend="engine", block_size=8, prefill_chunk=12)
    with pytest.raises(ValueError, match="multiple of block_size"):
        ps.validate()
    # aligned chunk is fine
    _cost_pool(backend="engine", block_size=8, prefill_chunk=16).validate()


@pytest.mark.parametrize("kw,match", [
    (dict(profiles=()), "profiles"),
    (dict(capacity=0), "capacity"),
    (dict(max_window=0), "max_window"),
    (dict(max_slots=0), "max_slots"),
    (dict(prompt_len=0), "prompt_len"),
    (dict(max_new=0), "max_new"),
    (dict(block_size=0), "block_size"),
    (dict(num_blocks=0), "num_blocks"),
    (dict(scrub_blocks=-1), "scrub_blocks"),
    (dict(watchdog_steps=0), "watchdog_steps"),
    (dict(prefill_energy_scale=-0.5), "prefill_energy_scale"),
])
def test_pool_validate_rejects_bad_field(kw, match):
    with pytest.raises(ValueError, match=match):
        _cost_pool(**kw).validate()


def test_fleet_validate_runs_from_build():
    # validation fires before any model/engine work, so a costmodel
    # fleet with a bad dt raises immediately
    spec = FleetSpec(pools=[_cost_pool()], dt=0.0)
    with pytest.raises(ValueError, match="dt must be > 0"):
        spec.build()


def test_fleet_validate_rejects_duplicate_pool_names():
    spec = FleetSpec(pools=[_cost_pool(), _cost_pool()])
    with pytest.raises(ValueError, match="duplicate pool name"):
        spec.validate()


def test_fleet_validate_rejects_bad_retry_policy():
    with pytest.raises(ValueError, match="backoff_s must be > 0"):
        FleetSpec(pools=[_cost_pool()],
                  retry={"default": {"backoff_s": 0.0}}).validate()
    with pytest.raises(ValueError, match="unknown RetryPolicy key"):
        FleetSpec(pools=[_cost_pool()],
                  retry={"default": {"backof_s": 0.1}}).validate()
    with pytest.raises(ValueError, match="max_attempts"):
        FleetSpec(pools=[_cost_pool()],
                  retry={"bulk": {"max_attempts": 0}}).validate()


def test_fleet_validate_rejects_fault_on_unknown_pool():
    spec = FleetSpec(pools=[_cost_pool()],
                     faults=[FaultSpec("ghost", at_s=1.0)])
    with pytest.raises(ValueError, match="unknown pool 'ghost'"):
        spec.validate()


def test_from_dict_rejects_unknown_keys():
    good = FleetSpec(pools=[_cost_pool()])
    d = good.to_dict()
    d["pools"][0]["blok_size"] = 8
    with pytest.raises(ValueError, match=r"PoolSpec.*blok_size"):
        FleetSpec.from_dict(d)
    d = good.to_dict()
    d["watchdogs"] = 1.0
    with pytest.raises(ValueError, match=r"FleetSpec.*watchdogs"):
        FleetSpec.from_dict(d)
    d = good.to_dict()
    d["faults"] = [{"pool": "p", "at_s": 1.0, "kindd": "pool"}]
    with pytest.raises(ValueError, match=r"FaultSpec.*kindd"):
        FleetSpec.from_dict(d)
    # the round-trip itself stays lossless
    assert FleetSpec.from_dict(good.to_dict()).to_dict() == good.to_dict()
