"""Flight recorder: span-chain closure on every terminal path (complete /
reject / energy-reject / drop / eviction / deferral), churn-proof
reconciliation of span energy and batch time against PoolCounters,
engine-stage nesting, Chrome trace export validity, the fleet
time-series ring, reservoir-sampled histograms, and the telemetry
snapshot schema golden."""
import json
import math

import numpy as np
import jax
import pytest

from repro.launch.route import vision_fleet_spec
from repro.models import transformer as T
from repro.obs import FleetTimeSeries, Tracer, chrome_trace
from repro.orbit import OrbitSpec, PhaseSpec, ScalingPolicy
from repro.router import SLO_CLASSES
from repro.router.telemetry import Histogram
from repro.serving import (FaultSpec, FleetSpec, PoolSpec, SLOClass,
                           open_loop)

from conftest import tiny_dense

PROMPT_LEN, MAX_NEW = 8, 6


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def lm_spec(**pool_kw):
    kw = dict(capacity=1, max_window=4, max_wait_s=0.0, max_slots=3,
              prompt_len=PROMPT_LEN, max_new=MAX_NEW, backend="engine")
    kw.update(pool_kw)
    return FleetSpec(pools=[PoolSpec("lm", ("tpu_v5e_bf16",), **kw)],
                     workload="transformer", seq_len=PROMPT_LEN)


def cost_spec(**kw):
    return FleetSpec(
        pools=[PoolSpec("board", ("mpsoc_dpu",), capacity=1,
                        max_window=4, max_wait_s=0.0)],
        workload="ursonet", **kw)


# ---------------------------------------------------------------------------
# Tracer unit behavior
# ---------------------------------------------------------------------------
def test_tracer_disabled_is_inert():
    tr = Tracer()
    tr.begin_request(1, 0.0)
    tr.begin(1, "queue", 0.0)
    tr.add(1, "serve", 0.0, 1.0)
    tr.event("mode", 0.5)
    tr.end_request(1, 1.0, "completed")
    assert tr.spans == [] and tr.outcomes == {}
    assert tr.summary()["spans"] == 0


def test_tracer_tree_nests_by_containment_and_closes_orphans():
    tr = Tracer(enabled=True)
    tr.begin_request(1, 0.0, slo="offline")
    tr.begin(1, "queue", 0.0, pool="p")
    tr.finish(1, "queue", 1.0)
    tr.begin(1, "serve", 1.0, pool="p")
    tr.add(1, "prefill_chunk", 1.1, 1.2, pool="p", tokens=8)
    tr.end_request(1, 2.0, "completed")          # serve left open on purpose
    assert tr.closed(1) and not tr.open_spans()
    tree = tr.trace(1)
    assert tree["stage"] == "request" and tree["outcome"] == "completed"
    assert {c["stage"] for c in tree["children"]} == {"queue", "serve"}
    serve = next(c for c in tree["children"] if c["stage"] == "serve")
    # the chunk nests under the serve span that contains it, and the
    # dangling serve span was closed at the terminal event, marked
    assert [c["stage"] for c in serve["children"]] == ["prefill_chunk"]
    assert serve["t1"] == 2.0 and serve["attrs"]["truncated"] is True


def test_tracer_stale_same_stage_span_closes_defensively():
    tr = Tracer(enabled=True)
    tr.begin(5, "queue", 0.0)
    tr.begin(5, "queue", 1.0)                    # re-open without finish
    first, second = tr.spans_for(5)
    assert first.t1 == 1.0 and first.attrs["truncated"] is True
    assert second.open


def test_tracer_span_cap_counts_dropped_but_chains_still_close():
    tr = Tracer(enabled=True, max_spans=2)
    tr.begin_request(1, 0.0)
    tr.begin(1, "queue", 0.0)
    tr.begin(1, "serve", 0.5)                    # over the cap -> dropped
    assert tr.dropped == 1
    tr.end_request(1, 1.0, "completed")
    assert tr.closed(1) and not tr.open_spans()
    s = tr.summary()
    assert s["spans"] == 2 and s["dropped"] == 1


def test_tracer_jsonl_round_trips(tmp_path):
    tr = Tracer(enabled=True)
    tr.begin_request(3, 0.25, slo="offline")
    tr.end_request(3, 0.5, "completed")
    path = tmp_path / "spans.jsonl"
    assert tr.to_jsonl(path) == 1
    rows = [json.loads(l) for l in path.read_text().splitlines()]
    assert rows[0]["rid"] == 3 and rows[0]["stage"] == "request"
    assert rows[0]["attrs"]["outcome"] == "completed"


# ---------------------------------------------------------------------------
# terminal paths through the fleet: every exit closes the chain
# ---------------------------------------------------------------------------
def test_completed_and_rejected_chains_close_through_fleet():
    client = cost_spec().build()
    client.enable_tracing()
    ok = client.submit(slo="bulk-reprocess")
    bad = client.submit(slo=SLOClass("impossible", max_latency_s=1e-9))
    client.drain()
    tr = client.tracer
    assert not tr.open_spans()
    assert tr.outcomes[ok.rid] == "completed"
    assert tr.outcomes[bad.rid] == "rejected"
    assert ok.trace()["outcome"] == "completed"
    # the rejected chain is just the root span, closed at submit time
    assert [s.stage for s in tr.spans_for(bad.rid)] == ["request"]
    assert not tr.spans_for(bad.rid)[0].open


def test_fault_drop_chain_closes_with_dropped_outcome():
    client = cost_spec(faults=[FaultSpec("board", at_s=0.001,
                                         duration_s=math.inf)]).build()
    client.enable_tracing()
    h = client.submit(slo="bulk-reprocess")
    client.drain()
    tr = client.tracer
    assert tr.outcomes[h.rid] == "dropped" and tr.closed(h.rid)
    assert not tr.open_spans()
    stages = {s.stage for s in tr.spans_for(h.rid)}
    assert "queue" in stages                     # it did reach a pool
    assert any(s.stage == "failover" for s in tr.spans)   # fleet marker


def test_deferred_chain_carries_defer_span_then_completes():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 1.0, 0.0),
                              PhaseSpec("sunlit", 9.0, 100.0)],
                      bucket_j=1.0, initial_frac=0.2,
                      conserve_frac=0.5, critical_frac=0.01)
    ospec.attach(client)
    client.enable_tracing()
    h = client.submit(slo="bulk-reprocess")      # parks through the eclipse
    assert h.result(max_s=30.0).admitted
    tr = client.tracer
    assert tr.outcomes[h.rid] == "completed" and not tr.open_spans()
    defer = next(s for s in tr.spans_for(h.rid) if s.stage == "defer")
    assert not defer.open and defer.duration_s > 0.9   # waited for sunlight
    assert any(s.stage == "mode" for s in tr.spans)    # mode-change marker


def test_energy_rejected_chain_closes_while_deferred_stays_open():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("eclipse", 100.0, 0.0)],
                      bucket_j=1.0, initial_frac=0.0,
                      conserve_frac=0.5, critical_frac=0.1)
    ospec.attach(client)
    client.enable_tracing()
    parked = client.submit(slo="bulk-reprocess")       # defers
    critical = client.submit(slo="downlink-critical")  # dry bucket: reject
    tr = client.tracer
    assert tr.outcomes[critical.rid] == "energy_rejected"
    assert tr.closed(critical.rid)
    # the parked request is legitimately still in flight: root + defer
    assert not tr.closed(parked.rid)
    assert {s.stage for s in tr.open_spans()} == {"request", "defer"}


# ---------------------------------------------------------------------------
# churn: faults + autoscaler retirements, chains still reconcile
# ---------------------------------------------------------------------------
def test_churn_every_chain_closes_and_totals_reconcile():
    """Open-loop traffic over the three-pool vision fleet while board-b
    takes an SEU mid-run and the autoscaler clones/retires board-a:
    every submitted request must end in a closed chain, and the spans
    must re-derive the fleet's aggregate energy and batch time."""
    spec = vision_fleet_spec(faults=[
        FaultSpec("board-b", at_s=0.3, duration_s=0.6)])
    client = spec.build()
    ospec = OrbitSpec(
        phases=[PhaseSpec("sunlit", 10.0, 1000.0)], bucket_j=1000.0,
        scaling=ScalingPolicy(template="board-a", min_pools=1, max_pools=3,
                              queue_high=4, queue_low=0, cooldown_s=0.05))
    ospec.attach(client)
    client.enable_tracing()
    classes = [SLO_CLASSES["downlink-critical"],
               SLO_CLASSES["background-science"],
               SLO_CLASSES["bulk-reprocess"]]
    handles = open_loop(client, classes, [0.2, 0.5, 0.3], rate_hz=500.0,
                        n_requests=150, seed=3)
    for _ in range(300):                         # idle tail: clones retire
        client.step()

    snap = client.telemetry
    assert snap["failovers"] >= 1                # the SEU actually hit
    assert snap["pools_added"] >= 1 and snap["pools_retired"] >= 1

    tr = client.tracer
    assert tr.dropped == 0
    assert not tr.open_spans()                   # the orphan invariant
    assert len(handles) == 150
    for h in handles:
        assert tr.closed(h.rid), f"rid {h.rid} never closed"
    # outcome conservation: every handle accounted for exactly once
    s = tr.summary()
    assert sum(s["outcomes"].values()) == len(handles)
    assert s["outcomes"]["completed"] == snap["completed"]

    # span-level energy re-derives the fleet total: each serve span
    # carries its equal split of the batch's launch-time charge (an
    # evicted request keeps its share — the joules were spent)
    counters = client.router.telemetry.pools
    serve = [sp for sp in tr.spans if sp.stage == "serve"]
    span_energy = sum(sp.attrs["energy_j"] for sp in serve)
    fleet_energy = sum(c.energy_j for c in counters.values())
    assert span_energy == pytest.approx(fleet_energy, rel=1e-6)

    # batch-time reconciliation: busy_s counts each batch once, so
    # dedup the per-request serve spans by batch id before summing
    by_bid = {sp.attrs["bid"]: sp.attrs["lat_s"] for sp in serve}
    fleet_busy = sum(c.busy_s for c in counters.values())
    assert sum(by_bid.values()) == pytest.approx(fleet_busy, rel=1e-6)

    # chain containment: no span outlives its root request span
    for h in handles:
        spans = tr.spans_for(h.rid)
        root = next(sp for sp in spans if sp.stage == "request")
        for sp in spans:
            assert sp.t0 >= root.t0 - 1e-9
            assert sp.t1 <= root.t1 + 1e-9


# ---------------------------------------------------------------------------
# engine pools: stage lanes + per-request engine detail nest in serve
# ---------------------------------------------------------------------------
def test_engine_trace_stage_lanes_and_nesting(model):
    client = lm_spec(max_prompt_len=4 * PROMPT_LEN).build(model=model)
    client.enable_tracing()
    rng = np.random.default_rng(7)
    h_long = client.submit(
        rng.integers(0, 256, 2 * PROMPT_LEN + 3).astype(np.int32),
        slo="offline", max_new=3)
    h_short = client.submit(
        rng.integers(0, 256, 5).astype(np.int32), slo="offline", max_new=3)
    client.drain()
    tr = client.tracer
    assert not tr.open_spans()
    assert tr.outcomes[h_long.rid] == "completed"
    assert tr.outcomes[h_short.rid] == "completed"
    # batch-level engine stages land on the pool lane (rid=None)
    lanes = [sp for sp in tr.spans if sp.rid is None]
    assert any(sp.stage == "decode_step" for sp in lanes)
    assert all(sp.pool == "lm" for sp in lanes)
    # the over-bucket prompt prefilled in chunks, attributed to the rid
    spans = tr.spans_for(h_long.rid)
    chunks = [sp for sp in spans if sp.stage == "prefill_chunk"]
    assert chunks
    serve = next(sp for sp in spans if sp.stage == "serve")
    for sp in chunks:                            # wall-time engine detail
        assert serve.t0 - 1e-9 <= sp.t0          # anchors inside the
        assert sp.t1 <= serve.t1 + 1e-9          # virtual serve span
    tree = h_long.trace()
    assert tree["stage"] == "request" and tree["children"]


def test_disaggregated_trace_attributes_stages_to_stage_pools(model):
    client = lm_spec(max_prompt_len=4 * PROMPT_LEN,
                     prefill_backend="engine").build(model=model)
    client.enable_tracing()
    prompt = np.random.default_rng(23).integers(
        0, 256, 2 * PROMPT_LEN + 3).astype(np.int32)
    h = client.submit(prompt, max_new=3)
    h.result()
    tr = client.tracer
    spans = tr.spans_for(h.rid)
    stages = {sp.stage for sp in spans}
    assert {"prefill_chunk", "handoff", "import"} <= stages
    # prefill-side stages carry the stage pool's name, decode side the
    # routed pool's — the co-processing split is visible per span
    assert {sp.pool for sp in spans
            if sp.stage in ("prefill_chunk", "handoff")} == {"lm.prefill"}
    assert next(sp.pool for sp in spans if sp.stage == "import") == "lm"
    # summed chunk energy is exactly the prefill stage counter's charge
    chunk_e = sum(sp.attrs["energy_j"] for sp in spans
                  if sp.stage == "prefill_chunk")
    pre = client.router.telemetry.pools["lm.prefill"]
    assert chunk_e == pytest.approx(pre.energy_j, rel=1e-6)


def test_response_handle_trace_none_when_recorder_off(model):
    client = lm_spec().build(model=model)
    h = client.submit(np.arange(4, dtype=np.int32), max_new=2)
    client.drain()
    assert h.trace() is None


# ---------------------------------------------------------------------------
# Chrome export
# ---------------------------------------------------------------------------
def test_chrome_trace_export_is_valid_and_laned(tmp_path):
    client = cost_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("sunlit", 0.5, 100.0),
                              PhaseSpec("eclipse", 0.5, 0.0)],
                      bucket_j=100.0)
    ospec.attach(client)
    client.enable_tracing()
    for _ in range(6):
        client.submit(slo="bulk-reprocess")
    client.drain()

    from repro.obs import export_chrome_trace
    path = tmp_path / "trace.json"
    trace = export_chrome_trace(client, path)
    reloaded = json.loads(path.read_text())      # valid JSON on disk
    assert reloaded["otherData"]["spans"] == len(client.tracer.spans)
    evs = reloaded["traceEvents"]
    for ev in evs:
        assert {"ph", "pid", "tid", "name"} <= set(ev)
        if ev["ph"] != "M":
            assert "ts" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # one process lane per pool plus the fleet lane, named via metadata
    names = {ev["args"]["name"] for ev in evs
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert names == {"fleet", "board"}
    # orbit phases ride the fleet lane as async begin/end pairs
    assert (sum(1 for ev in evs if ev["ph"] == "b")
            == sum(1 for ev in evs if ev["ph"] == "e") > 0)
    # the always-on time-series feeds counter tracks
    assert any(ev["ph"] == "C" and ev["name"] == "queue_depth"
               for ev in evs)
    assert trace["traceEvents"] == evs


# ---------------------------------------------------------------------------
# fleet time-series
# ---------------------------------------------------------------------------
def test_timeseries_ring_bounds_decimation_and_rates():
    client = cost_spec().build()
    for _ in range(4):
        client.submit(slo="bulk-reprocess")
    ts = FleetTimeSeries(maxlen=8, interval_s=0.5)
    for i in range(100):                         # 0.25 s ticks: exact in
        took = ts.observe(client, i * 0.25)      # binary, no fp drift
        assert took == (i % 2 == 0)              # decimated to every 0.5 s
    assert len(ts) == 8 and ts.total_samples == 50   # ring aged 42 out
    tvals = ts.series("t")
    assert tvals == sorted(tvals) and len(ts.tokens_per_s()) == 7
    s = ts.summary()
    assert s["retained"] == 8 and s["samples"] == 50
    assert s["pools_min"] == s["pools_max"] == 1
    assert s["mode_last"] == "nominal" and s["bucket_frac_last"] is None


def test_timeseries_sampled_by_client_and_embedded_in_orbit_report():
    client = vision_fleet_spec().build()
    ospec = OrbitSpec(phases=[PhaseSpec("sunlit", 1.0, 100.0)],
                      bucket_j=100.0)
    ctrl = ospec.attach(client)
    client.submit(slo="bulk-reprocess")
    client.drain()
    assert len(client.timeseries) > 0            # advance() samples it
    assert client.timeseries.series("bucket_frac")[-1] is not None
    rep = ctrl.report()
    assert rep["timeseries"]["samples"] == client.timeseries.total_samples
    assert rep["timeseries"]["mode_last"] == ctrl.mode


# ---------------------------------------------------------------------------
# FleetSpec: declarative recorder enablement
# ---------------------------------------------------------------------------
def test_fleet_spec_trace_field_round_trips_and_enables():
    spec = cost_spec(trace=True)
    d = spec.to_dict()
    restored = FleetSpec.from_dict(json.loads(json.dumps(d)))
    assert restored.to_dict() == d and restored.trace is True
    client = restored.build()
    assert client.tracer.enabled
    h = client.submit(slo="bulk-reprocess")
    client.drain()
    assert client.tracer.closed(h.rid)
    assert cost_spec().to_dict()["trace"] is False


# ---------------------------------------------------------------------------
# histogram satellites: reservoir sampling + cached percentiles
# ---------------------------------------------------------------------------
def test_histogram_reservoir_is_deterministic_and_reports_dropped():
    h1, h2 = Histogram(max_samples=100), Histogram(max_samples=100)
    for v in range(1000):
        h1.record(v)
        h2.record(v)
    assert h1.samples == h2.samples              # seeded reservoir
    assert h1.count == 1000 and h1.dropped == 900
    assert len(h1.samples) == 100
    s = h1.summary()
    assert s["count"] == 1000 and s["dropped"] == 900
    # the reservoir keeps the whole run, not its first 100 values
    assert h1.percentile(50) > 100


def test_histogram_under_capacity_drops_nothing():
    h = Histogram(max_samples=100)
    for v in range(50):
        h.record(v)
    assert h.count == 50 and h.dropped == 0
    assert h.summary()["dropped"] == 0


def test_histogram_percentile_cache_invalidates_on_record():
    h = Histogram()
    for v in (5.0, 1.0, 3.0):
        h.record(v)
    assert h.percentile(50) == 3.0               # caches the sorted view
    h.record(0.0)
    h.record(0.0)
    assert h.percentile(50) == 1.0               # record dirtied the cache
    assert h.percentile(0) == 0.0 and h.percentile(100) == 5.0
    assert h.mean == pytest.approx(9.0 / 5)


# ---------------------------------------------------------------------------
# snapshot schema golden: the keys monitors depend on
# ---------------------------------------------------------------------------
FLEET_KEYS = {
    "admitted", "rejected", "completed", "violations", "dropped",
    "drops_by_reason", "failovers", "reschedules", "retries",
    "watchdog_trips", "bitflips_detected", "blocks_quarantined",
    "handoffs_replayed", "prefix_hits", "prefix_hit_rate",
    "energy_deferred", "energy_rejected",
    "pools_added", "pools_retired", "energy_j", "queue_depth", "pools",
    "latency_by_class", "violations_by_class", "slis", "alerts",
}
DROP_REASONS = {"no_route", "retry_exhausted", "dry_battery", "deadline"}
POOL_KEYS = {
    "dispatched", "completed", "evicted", "batches", "energy_j", "busy_s",
    "tokens_generated", "tokens_per_s", "decode_tokens", "decode_s",
    "decode_tokens_per_s", "prefill_tokens", "deferrals",
    "queue_depth_now", "load_now", "bitflips_detected",
    "blocks_quarantined", "watchdog_trips", "handoffs_replayed",
    "prefix_hits", "prefix_lookups", "prefix_hit_rate",
    "imports_by_shard", "queue_depth", "batch_size", "slot_occupancy",
}
HIST_KEYS = {"count", "mean", "p50", "p99", "dropped"}
# golden-signal SLI schema (repro.obs.slo — same lockstep contract)
SLI_SCOPES = {"fleet", "by_class", "by_pool"}
SLI_KEYS = {
    "completed", "dropped", "rejected", "violated", "retries",
    "ttft_s", "itl_s", "queue_wait_s", "e2e_s",
}
ALERT_KEYS = {"firing", "firing_count", "pages_fired", "warns_fired",
              "cleared"}


def test_telemetry_snapshot_schema_golden():
    """The snapshot dict is the contract every external consumer reads
    (orbit controller, benches, CI gates, dashboards).  Growing it is
    fine — this golden makes renames and removals a deliberate act:
    update the key sets here in the same change that edits
    ``Telemetry.snapshot()`` / ``PoolCounters.summary()``."""
    client = cost_spec().build()
    client.submit(slo="bulk-reprocess")
    client.drain()
    snap = client.telemetry
    assert set(snap) == FLEET_KEYS
    # reason codes are zero-initialized so the schema is traffic-stable
    assert set(snap["drops_by_reason"]) >= DROP_REASONS
    pool = snap["pools"]["board"]
    assert set(pool) == POOL_KEYS
    for hist_key in ("queue_depth", "batch_size", "slot_occupancy"):
        assert set(pool[hist_key]) == HIST_KEYS
    assert all(set(v) == HIST_KEYS
               for v in snap["latency_by_class"].values())
    # SLI / alert plane: stable shape with zero traffic dependence
    assert set(snap["slis"]) == SLI_SCOPES
    assert set(snap["slis"]["fleet"]) == SLI_KEYS
    for scope in snap["slis"]["by_class"].values():
        assert set(scope) == SLI_KEYS
        for sig in ("ttft_s", "itl_s", "queue_wait_s", "e2e_s"):
            assert set(scope[sig]) == HIST_KEYS
    assert set(snap["alerts"]) == ALERT_KEYS
    json.dumps(snap)                             # JSON-serializable whole


# ---------------------------------------------------------------------------
# per-request golden-signal stamps on the handle
# ---------------------------------------------------------------------------
def test_handle_telemetry_ttft_and_e2e_stamps_streaming(model):
    """Engine pools stream tokens before completion, so the handle's
    TTFT stamp lands strictly inside the e2e latency."""
    client = lm_spec().build(model=model)
    h = client.submit(np.arange(PROMPT_LEN, dtype=np.int32),
                      max_new=MAX_NEW)
    assert h.telemetry["ttft_s"] is None         # nothing delivered yet
    assert h.telemetry["e2e_s"] is None
    h.result()
    t = h.telemetry
    assert t["ttft_s"] is not None and t["ttft_s"] > 0
    assert t["e2e_s"] == pytest.approx(t["latency_s"])
    assert t["ttft_s"] <= t["e2e_s"]
    # the same signals landed in the SLI registry's fleet scope
    fleet = client.telemetry["slis"]["fleet"]
    assert fleet["completed"] == 1
    assert fleet["ttft_s"]["count"] == 1
    assert fleet["ttft_s"]["p50"] <= fleet["e2e_s"]["p50"]


def test_handle_telemetry_ttft_equals_e2e_on_costmodel():
    """Hook-less (cost-model) pools deliver everything at completion:
    TTFT honestly degenerates to the e2e latency instead of lying."""
    client = cost_spec().build()
    h = client.submit(slo="bulk-reprocess")
    client.drain()
    t = h.telemetry
    assert t["e2e_s"] > 0
    assert t["ttft_s"] == pytest.approx(t["e2e_s"])


# ---------------------------------------------------------------------------
# time-series rates across pool retirement (regression)
# ---------------------------------------------------------------------------
def test_timeseries_rates_survive_pool_retirement_and_compaction():
    """The fleet decode cumulative is differentiated per pool before
    summing: a retired pool's counters leaving ``telemetry.pools``
    (history compaction) must not step the cumulative backward and
    spike ``tokens_per_s`` negative — the pre-fix implementation summed
    live counters and did exactly that."""
    client = vision_fleet_spec().build()
    tel = client.router.telemetry
    for _ in range(4):
        client.submit(slo="background-science")
    for _ in range(20):                  # decode progress on both boards
        tel.pools["board-a"].decode_tokens += 5
        tel.pools["board-b"].decode_tokens += 3
        client.step()
    client.retire_pool("board-b")
    client.drain()
    # history compaction: the retired pool's counters leave telemetry
    tel.pools.pop("board-b", None)
    for _ in range(10):
        tel.pools["board-a"].decode_tokens += 5
        client.step()
    rates = client.timeseries.tokens_per_s()
    assert rates and all(r >= 0.0 for r in rates)
    assert max(rates) > 0
    toks = client.timeseries.series("decode_tokens")
    assert all(b >= a for a, b in zip(toks, toks[1:]))
