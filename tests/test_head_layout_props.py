"""Property tests for the TP head-padding layout (DESIGN.md §5): for ANY
(heads, kv_heads, tp) with kv | heads, the padded layout must be exact —
every real q head appears once, mapped to its true kv head, and all
sharding divisibility constraints hold."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.attention import head_layout, kv_slot_map, q_slot_map


@st.composite
def _hkv(draw):
    kv = draw(st.integers(1, 64))
    group = draw(st.integers(1, 16))
    h = kv * group
    tp = draw(st.sampled_from([1, 2, 4, 8, 16, 32]))
    return h, kv, tp


@given(_hkv())
@settings(deadline=None, max_examples=200)
def test_layout_divisibility_and_coverage(hkv):
    h, kv, tp = hkv
    lay = head_layout(h, kv, tp)
    # sharding constraints
    assert lay.Hp % tp == 0 and lay.KVp % tp == 0
    assert lay.KVp % kv == 0 and lay.Hp % lay.KVp == 0
    assert lay.Hp >= h and lay.KVp >= min(kv, lay.KVp)
    # every real q head appears exactly once
    smap = np.asarray(q_slot_map(lay))
    real = smap[smap >= 0]
    assert sorted(real.tolist()) == list(range(h))
    # padded mapping preserves the true q->kv association
    kmap = np.asarray(kv_slot_map(lay))
    g = h // kv
    gp = lay.gp
    for slot, q_real in enumerate(smap):
        if q_real < 0:
            continue
        true_kv = q_real // g
        padded_kv = slot // gp
        assert kmap[padded_kv] == true_kv, (h, kv, tp, slot, q_real)


@given(_hkv())
@settings(deadline=None, max_examples=100)
def test_layout_shard_locality(hkv):
    """Each TP shard's q heads use only that shard's kv heads."""
    h, kv, tp = hkv
    lay = head_layout(h, kv, tp)
    q_per_shard = lay.Hp // tp
    kv_per_shard = lay.KVp // tp
    for shard in range(tp):
        q_slots = range(shard * q_per_shard, (shard + 1) * q_per_shard)
        for slot in q_slots:
            padded_kv = slot // lay.gp
            assert padded_kv // kv_per_shard == shard
