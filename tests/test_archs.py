"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, output shapes + finiteness.  Also decode-vs-forward exactness
per family and the MPAI plan applied to every arch (§Arch-applicability:
the technique applies to all 10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.core.partition import PartitionPlan
from repro.core.qat import serve_plan, train_plan
from repro.models import transformer as T
from repro.models.frontends import synthetic_frontend_embeds

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    fe = (synthetic_frontend_embeds(cfg, b)
          if cfg.frontend != "none" else None)
    return toks, fe


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = T.model_init(KEY, cfg)
    toks, fe = _batch(cfg)
    out = T.forward(params, cfg, toks, frontend_embeds=fe)
    exp_seq = toks.shape[1] + (cfg.frontend_tokens if fe is not None else 0)
    assert out.logits.shape == (2, exp_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = T.model_init(KEY, cfg)
    toks, fe = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, toks, toks, frontend_embeds=fe))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all())
               for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).with_(frontend="none",
                                             frontend_tokens=0)
    params = T.model_init(KEY, cfg)
    toks, _ = _batch(cfg, s=12)
    tok1 = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0,
                              cfg.vocab_size)
    cache = T.init_cache(cfg, 2, 24)
    out = T.prefill(params, cfg, toks, cache)
    step = T.decode_step(params, cfg, tok1, out.cache)
    ref = T.forward(params, cfg, jnp.concatenate([toks, tok1], 1))
    a = np.asarray(step.logits[:, 0], np.float32)
    b = np.asarray(ref.logits[:, -1], np.float32)
    # bf16 forward; rwkv decode uses the exact recurrence vs chunked scan
    np.testing.assert_allclose(a, b, atol=0.05, rtol=0.05)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_mpai_plan_applies_to_every_arch(arch):
    """§Arch-applicability: int8 backbone + bf16 head trains and serves."""
    cfg = get_config(arch, smoke=True)
    params = T.model_init(KEY, cfg)
    toks, fe = _batch(cfg)
    period = T.pattern_period(cfg)
    base = PartitionPlan.mpai(cfg.num_layers,
                              split=max(period, cfg.num_layers - period))
    # QAT train step
    tp = train_plan(base)
    loss = T.loss_fn(params, cfg, toks, toks, plan=tp, frontend_embeds=fe)
    assert bool(jnp.isfinite(loss))
    # int8 serve forward
    sp = serve_plan(base)
    out = T.forward(params, cfg, toks, plan=sp, frontend_embeds=fe)
    assert bool(jnp.isfinite(out.logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "rwkv6-3b"])
def test_long_context_archs_have_o1ish_state(arch):
    """long_500k eligibility: decode state must not grow with context
    (attention caches excluded — jamba's 4 windowless attn layers hold the
    only length-proportional state)."""
    cfg = get_config(arch, smoke=True)
    assert get_config(arch).supports_long_context()
    params = T.model_init(KEY, cfg)
    cache = T.init_cache(cfg, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    out = T.decode_step(params, cfg, tok, cache)
    # state sizes unchanged after a step
    s0 = jax.tree_util.tree_map(lambda a: a.shape, cache)
    s1 = jax.tree_util.tree_map(lambda a: a.shape, out.cache)
    assert s0 == s1


def test_full_configs_match_assignment_table():
    """Exact geometry from the assignment block."""
    rows = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d, arch
        assert cfg.num_heads == h and cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch
    # MoE structure
    for arch, (e, k) in {"jamba-v0.1-52b": (16, 2), "olmoe-1b-7b": (64, 8),
                         "moonshot-v1-16b-a3b": (64, 6)}.items():
        moe = get_config(arch).moe
        assert moe.num_experts == e and moe.top_k == k, arch


def test_long_500k_skip_rule():
    from repro.configs import cells
    all_cells = cells(include_skipped=True)
    skipped = {(a, s) for a, s, sk in all_cells if sk}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("qwen3-14b", "long_500k") in skipped
    assert ("rwkv6-3b", "long_500k") not in skipped
    assert ("jamba-v0.1-52b", "long_500k") not in skipped
    assert len(all_cells) == 40
