"""fleetlint: the analyzer's own test gate.

Fixture files under ``tests/fixtures/fleetlint`` carry one known
violation per pass (true-positive guard) next to a clean twin
(false-positive guard); scratch trees exercise the project passes,
suppression round-trip, and the CLI; and the meta-test pins the live
``src/repro`` tree lint-clean against the checked-in baseline — the
same gate CI runs, so a PR that introduces a wall-clock read or a host
sync fails here first.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (BaselineError, DEFAULT_BASELINE, run_lint,
                            load_baseline)
from repro.analysis.core import default_root

REPO = default_root()
FIXTURES = os.path.join(REPO, "tests", "fixtures", "fleetlint")


def scratch_tree(tmp_path, files):
    """Materialize ``{relpath: content}`` under tmp_path as a repo."""
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content)
    return str(tmp_path)


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


def lint_fixture(tmp_path, name: str, rel="src/repro/fx/mod.py"):
    root = scratch_tree(tmp_path, {rel: fixture(name)})
    report = run_lint(root=root, baseline_path=None)
    return [f for f in report.findings if f.path == rel]


# ---------------------------------------------------------------------------
# file passes: one true-positive fixture + one clean twin each
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,codes", [
    ("clock_bad.py", {"VCP001", "VCP002"}),
    ("jit_bad.py", {"JIT001", "JIT002", "JIT003", "JIT004", "JIT005"}),
    ("alloc_bad.py", {"ALC001"}),
    ("exc_bad.py", {"EXC001", "EXC002"}),
])
def test_fixture_violations_all_detected(tmp_path, name, codes):
    found = {f.code for f in lint_fixture(tmp_path, name)}
    assert codes <= found, f"{name}: wanted {codes}, got {found}"


@pytest.mark.parametrize("name", ["clock_clean.py", "jit_clean.py",
                                  "alloc_clean.py", "exc_clean.py"])
def test_clean_twins_produce_no_findings(tmp_path, name):
    assert lint_fixture(tmp_path, name) == []


def test_exc_hot_path_rejects_even_accounted_broad_except(tmp_path):
    # the same broad-except shapes the clean twin allows off the hot
    # path are EXC003 findings on router/ modules
    rel = "src/repro/router/worker.py"
    findings = lint_fixture(tmp_path, "exc_clean.py", rel=rel)
    assert {f.code for f in findings} == {"EXC003"}
    assert len(findings) == 2            # `accounted` and `rewrapped`


# ---------------------------------------------------------------------------
# acceptance injections: the exact regressions the issue names
# ---------------------------------------------------------------------------
def test_injected_wall_clock_in_router_dispatch_is_caught(tmp_path):
    with open(os.path.join(REPO, "src/repro/router/dispatch.py")) as f:
        src = f.read()
    assert "import time" not in src
    src = src.replace(
        "from __future__ import annotations",
        "from __future__ import annotations\nimport time", 1)
    src += "\n\ndef _leak():\n    return time.time()\n"
    rel = "src/repro/router/dispatch.py"
    root = scratch_tree(tmp_path, {rel: src})
    report = run_lint(root=root, baseline_path=None)
    hits = [f for f in report.findings if f.code == "VCP001"]
    assert hits and hits[0].symbol == "_leak"


def test_injected_host_bool_in_fused_decode_is_caught(tmp_path):
    with open(os.path.join(REPO, "src/repro/runtime/serve.py")) as f:
        src = f.read()
    marker = "def _decode_greedy(p, toks, caches):"
    assert marker in src
    src = src.replace(
        marker, marker + "\n            _sync = bool(toks)", 1)
    rel = "src/repro/runtime/serve.py"
    root = scratch_tree(tmp_path, {rel: src})
    report = run_lint(root=root, baseline_path=None, passes=["jit"])
    hits = [f for f in report.findings if f.code == "JIT001"]
    assert hits, "traced-value bool() in the fused decode path missed"
    assert any("_decode_greedy" in f.symbol for f in hits)


# ---------------------------------------------------------------------------
# project passes: kernel contracts + telemetry schema
# ---------------------------------------------------------------------------
def _mutated_quant(transform):
    with open(os.path.join(REPO, "src/repro/kernels/quant.py")) as f:
        return transform(f.read())


def _kernel_findings(tmp_path, src):
    root = scratch_tree(tmp_path, {"src/repro/kernels/quant.py": src})
    report = run_lint(root=root, baseline_path=None, passes=["kernel"])
    return report.findings


def test_kernel_contract_clean_on_real_kernel(tmp_path):
    src = _mutated_quant(lambda s: s)
    findings = [f for f in _kernel_findings(tmp_path, src)
                if f.code != "KRN002"]   # other contracts stale in scratch
    assert findings == []


def test_kernel_contract_stale_entries_reported(tmp_path):
    codes = {f.code for f in _kernel_findings(tmp_path,
                                              _mutated_quant(lambda s: s))}
    assert "KRN002" in codes             # 5 absent wrappers -> stale


def test_kernel_contract_catches_dropped_divisibility_assert(tmp_path):
    src = _mutated_quant(
        lambda s: s.replace("assert m % bm == 0, (m, bm)", "pass"))
    assert "KRN010" in {f.code for f in _kernel_findings(tmp_path, src)}


def test_kernel_contract_catches_dtype_drift(tmp_path):
    src = _mutated_quant(lambda s: s.replace(
        "jax.ShapeDtypeStruct((m, k), jnp.int8)",
        "jax.ShapeDtypeStruct((m, k), jnp.float32)"))
    assert "KRN011" in {f.code for f in _kernel_findings(tmp_path, src)}


def test_kernel_contract_catches_grid_rank_change(tmp_path):
    src = _mutated_quant(
        lambda s: s.replace("grid = (m // bm,)", "grid = (m // bm, 1)"))
    assert "KRN003" in {f.code for f in _kernel_findings(tmp_path, src)}


def test_kernel_contract_catches_unknown_wrapper(tmp_path):
    src = _mutated_quant(lambda s: s.replace(
        "def rowwise_quant_pallas(", "def rogue_quant_pallas("))
    assert "KRN001" in {f.code for f in _kernel_findings(tmp_path, src)}


TEL_SRC = '''\
class Histogram:
    def summary(self):
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                "dropped": 0}


class PoolCounters:
    def summary(self):
        return {"dispatched": 0}


class Telemetry:
    def __init__(self):
        self.drops_by_reason = {"no_route": 0}

    def snapshot(self):
        return {"admitted": 0}
'''

TEL_SLO_SRC = '''\
class SLIScope:
    def summary(self):
        return {"completed": 0}


class SLIRegistry:
    def summary(self):
        return {"fleet": {}, "by_class": {}, "by_pool": {}}


class AlertBus:
    def snapshot(self):
        return {"firing": [], "firing_count": 0}
'''

TEL_GOLDEN = '''\
FLEET_KEYS = {"admitted"}
DROP_REASONS = {"no_route"}
POOL_KEYS = {"dispatched"}
HIST_KEYS = {"count", "mean", "p50", "p99", "dropped"}
SLI_KEYS = {"completed"}
SLI_SCOPES = {"fleet", "by_class", "by_pool"}
ALERT_KEYS = {"firing", "firing_count"}
'''


def _tel_findings(tmp_path, tel_src=TEL_SRC, golden=TEL_GOLDEN,
                  slo_src=TEL_SLO_SRC):
    root = scratch_tree(tmp_path, {
        "src/repro/router/telemetry.py": tel_src,
        "src/repro/obs/slo.py": slo_src,
        "tests/test_obs.py": golden,
    })
    report = run_lint(root=root, baseline_path=None, passes=["telemetry"])
    return report.findings


def test_telemetry_schema_in_sync_is_clean(tmp_path):
    assert _tel_findings(tmp_path) == []


def test_telemetry_written_key_missing_from_golden(tmp_path):
    src = TEL_SRC.replace('return {"admitted": 0}',
                          'return {"admitted": 0, "new_counter": 0}')
    findings = _tel_findings(tmp_path, tel_src=src)
    assert [f.code for f in findings] == ["TEL001"]
    assert "new_counter" in findings[0].message


def test_telemetry_golden_key_without_writer(tmp_path):
    golden = TEL_GOLDEN.replace('{"admitted"}', '{"admitted", "renamed"}')
    findings = _tel_findings(tmp_path, golden=golden)
    assert [f.code for f in findings] == ["TEL002"]
    assert "renamed" in findings[0].message


def test_telemetry_drop_reason_drift_both_directions(tmp_path):
    src = TEL_SRC.replace('{"no_route": 0}', '{"other": 0}')
    codes = sorted(f.code for f in _tel_findings(tmp_path, tel_src=src))
    assert codes == ["TEL001", "TEL002"]


def test_telemetry_slo_writer_key_missing_from_golden(tmp_path):
    # the SLO plane's writers are under the same lockstep contract as
    # the classic snapshot: a new AlertBus key must land in ALERT_KEYS
    src = TEL_SLO_SRC.replace('"firing_count": 0}',
                              '"firing_count": 0, "snoozed": 0}')
    findings = _tel_findings(tmp_path, slo_src=src)
    assert [f.code for f in findings] == ["TEL001"]
    assert "snoozed" in findings[0].message
    assert findings[0].path.endswith("obs/slo.py")


def test_telemetry_missing_slo_anchor_file_is_flagged(tmp_path):
    root = scratch_tree(tmp_path, {
        "src/repro/router/telemetry.py": TEL_SRC,
        "tests/test_obs.py": TEL_GOLDEN,
    })
    report = run_lint(root=root, baseline_path=None, passes=["telemetry"])
    assert "TEL003" in {f.code for f in report.findings}


# ---------------------------------------------------------------------------
# suppression baseline round-trip
# ---------------------------------------------------------------------------
def test_suppression_round_trip(tmp_path):
    rel = "src/repro/fx/mod.py"
    root = scratch_tree(tmp_path, {rel: fixture("clock_bad.py")})
    report = run_lint(root=root, baseline_path=None)
    keys = sorted({f.key for f in report.findings})
    assert keys, "fixture produced no findings to suppress"

    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"suppressions": [
        {"key": k, "reason": "fixture: sanctioned for the round-trip test"}
        for k in keys]}))
    report = run_lint(root=root, baseline_path=str(baseline))
    assert report.findings == [] and report.clean
    assert {f.key for f, _ in report.suppressed} == set(keys)


def test_baseline_entry_without_reason_is_rejected(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suppressions": [{"key": "a::VCP001::b",
                                               "reason": "  "}]}))
    with pytest.raises(BaselineError, match="no reason"):
        load_baseline(str(p))


def test_baseline_duplicate_key_is_rejected(tmp_path):
    entry = {"key": "a::VCP001::b", "reason": "x"}
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suppressions": [entry, entry]}))
    with pytest.raises(BaselineError, match="duplicate"):
        load_baseline(str(p))


def test_stale_suppression_fails_full_run_but_not_diff_slice(tmp_path):
    rel = "src/repro/fx/clean.py"
    root = scratch_tree(tmp_path, {rel: fixture("clock_clean.py")})
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"suppressions": [
        {"key": f"{rel}::VCP001::gone", "reason": "no longer exists"}]}))
    full = run_lint(root=root, baseline_path=str(baseline))
    assert full.stale_suppressions == [f"{rel}::VCP001::gone"]
    assert not full.clean
    sliced = run_lint(root=root, files=[rel], baseline_path=str(baseline))
    assert sliced.stale_suppressions == [] and sliced.clean


def test_suppression_key_survives_line_churn(tmp_path):
    rel = "src/repro/fx/mod.py"
    root = scratch_tree(tmp_path, {rel: fixture("clock_bad.py")})
    before = {f.key for f in run_lint(root=root,
                                      baseline_path=None).findings}
    # prepend 5 lines: every lineno shifts, no key changes
    shifted = "# pad\n" * 5 + fixture("clock_bad.py")
    root = scratch_tree(tmp_path, {rel: shifted})
    after = {f.key for f in run_lint(root=root,
                                     baseline_path=None).findings}
    assert before == after


# ---------------------------------------------------------------------------
# the meta-test: the live tree ships lint-clean
# ---------------------------------------------------------------------------
def test_live_tree_is_lint_clean_against_baseline():
    report = run_lint(root=REPO)
    assert report.parse_errors == []
    assert report.stale_suppressions == []
    assert report.findings == [], (
        "live src/repro tree has unsuppressed fleetlint findings:\n"
        + "\n".join(f"{f.path}:{f.line}: {f.code} {f.message}"
                    for f in report.findings))
    # every suppression is load-bearing and justified
    assert report.suppressed, "baseline should cover the sanctioned sites"
    for _, reason in report.suppressed:
        assert reason.strip()


def test_live_baseline_file_is_well_formed():
    entries = load_baseline(os.path.join(REPO, DEFAULT_BASELINE))
    for key, reason in entries.items():
        assert key.count("::") == 2, key
        assert reason.strip(), key


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _cli(args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                          capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_exits_zero_on_live_tree():
    res = _cli([])
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_exits_nonzero_per_fixture_violation(tmp_path):
    root = scratch_tree(tmp_path, {
        "src/repro/fx/mod.py": fixture("clock_bad.py")})
    res = _cli(["--root", str(root), "--no-baseline", "--json"])
    assert res.returncode == 1
    payload = json.loads(res.stdout)
    assert payload["clean"] is False
    assert payload["counts"]["by_pass"]["clock"] >= 2
    assert all(f["key"].count("::") == 2 for f in payload["findings"])


def test_cli_json_report_written_to_output(tmp_path):
    out = tmp_path / "LINT_report.json"
    res = _cli(["--output", str(out)])
    assert res.returncode == 0
    payload = json.loads(out.read_text())
    assert payload["clean"] is True and payload["files_scanned"] > 50


def test_cli_rejects_unknown_pass():
    assert _cli(["--pass", "nope"]).returncode == 2
