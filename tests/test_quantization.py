"""Property tests (hypothesis) for the int8 quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.precision import PrecisionPolicy
from repro.core.quantization import (QTensor, fake_quant, pdot, quantize,
                                     quantize_params)

ARRS = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=3,
                                               min_side=1, max_side=16),
                  elements=st.floats(-1e3, 1e3, width=32))


@settings(deadline=None, max_examples=50)
@given(ARRS)
def test_roundtrip_error_bounded_by_half_scale(x):
    qt = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(qt.dequantize(jnp.float32)) - x)
    assert (err <= np.asarray(qt.scale) / 2 + 1e-6).all()


@settings(deadline=None, max_examples=50)
@given(ARRS)
def test_quantize_idempotent(x):
    qt = quantize(jnp.asarray(x))
    qt2 = quantize(qt.dequantize(jnp.float32))
    np.testing.assert_array_equal(np.asarray(qt.values), np.asarray(qt2.values))


@settings(deadline=None, max_examples=30)
@given(ARRS)
def test_values_in_int8_range(x):
    qt = quantize(jnp.asarray(x), channel_axis=-1)
    v = np.asarray(qt.values)
    assert v.dtype == np.int8 and v.min() >= -127 and v.max() <= 127


def test_per_channel_beats_per_tensor_on_skewed_weights():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    w[:, 0] *= 100.0                         # one hot channel
    pc = quantize(jnp.asarray(w), channel_axis=-1)
    pt = quantize(jnp.asarray(w), channel_axis=None)
    err_pc = np.abs(np.asarray(pc.dequantize(jnp.float32)) - w).mean()
    err_pt = np.abs(np.asarray(pt.dequantize(jnp.float32)) - w).mean()
    assert err_pc < err_pt / 10


def test_fake_quant_is_straight_through():
    x = jnp.linspace(-2, 2, 32).reshape(4, 8)
    g = jax.grad(lambda a: jnp.sum(fake_quant(a) ** 2))(x)
    # STE: d/dx sum(fq(x)^2) = 2*fq(x) (identity jacobian through the quant)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(fake_quant(x)),
                               atol=1e-6)


def test_pdot_quant_close_to_raw():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    raw = pdot(x, w, PrecisionPolicy.fp32())
    q = pdot(x, w, PrecisionPolicy.int8())
    rel = np.abs(np.asarray(q, np.float32) - np.asarray(raw)).mean() / \
        np.abs(np.asarray(raw)).mean()
    assert rel < 0.05                        # int8 noise, not garbage


def test_pdot_fake_matches_quant_forward():
    """QAT sees the same forward numerics it will serve with."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    fake = pdot(x, w, PrecisionPolicy.int8_qat())
    quant = pdot(x, w, PrecisionPolicy.int8())
    # fake-quant multiplies in bf16; the real path accumulates in int32 —
    # agreement is bounded by bf16 resolution of the accumulated values
    a, b = np.asarray(fake, np.float32), np.asarray(quant, np.float32)
    assert np.abs(a - b).max() / max(np.abs(b).max(), 1e-6) < 0.02


def test_quantize_params_matrices_only():
    params = {"w": jnp.ones((8, 8)), "scale": jnp.ones((8,)),
              "nested": {"emb": jnp.ones((4, 4, 4))}}
    qp = quantize_params(params)
    assert isinstance(qp["w"], QTensor)
    assert isinstance(qp["nested"]["emb"], QTensor)
    assert not isinstance(qp["scale"], QTensor)
