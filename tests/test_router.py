"""Fleet router: SLO admission, least-loaded routing, failover, and the
Pareto-optimality of every dispatched plan."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import LayerCost, layer_costs_from_convspecs
from repro.core.scheduler import (ScheduledPlan, pareto_frontier,
                                  plan_profiles, price_assignments,
                                  reschedule_over_subset, schedule)
from repro.models.cnn import ursonet_table1_layers
from repro.router import (AcceleratorPool, CostModelExecutor,
                          FailoverController, PoolState, RetryPolicy,
                          Router, RouterRequest, SLOClass, select_plan)
from repro.runtime.fault import PoolFault, PoolFaultInjector

from conftest import tiny_dense


def _layers(n=6):
    return [LayerCost(f"l{i}", 1e9, 1e6, 1e5, 1e5) for i in range(n)]


def _pool(name, profiles, layers, **kw):
    kw.setdefault("capacity", 2)
    kw.setdefault("max_wait_s", 0.01)
    return AcceleratorPool(name, profiles, CostModelExecutor(layers), **kw)


RELAXED = SLOClass("relaxed", max_latency_s=30.0)
ACC_TIGHT = SLOClass("acc-tight", max_latency_s=30.0,
                     max_accuracy_penalty=0.05)
CRITICAL = SLOClass("critical", max_latency_s=0.2, priority=2)


def _drive(router, fc, reqs, dt=0.002, t_end=120.0):
    t, i = 0.0, 0
    while i < len(reqs) or router.outstanding or (fc and fc.pending_faults):
        t += dt
        if fc:
            fc.poll(t)
        while i < len(reqs) and reqs[i].arrival_s <= t:
            router.submit(reqs[i], t)
            i += 1
        router.step(t)
        assert t < t_end, "router failed to drain"
    return t


# ---------------------------------------------------------------------------
# scheduler satellites: skyline frontier + subset rescheduling + re-pricing
# ---------------------------------------------------------------------------
def _brute_frontier(plans):
    out = [p for p in plans
           if not any(q.dominates(p) for q in plans if q is not p)]
    seen, uniq = set(), []
    for p in sorted(out, key=lambda p: (p.latency_s, p.energy_j,
                                        p.accuracy_penalty)):
        key = (round(p.latency_s, 12), round(p.energy_j, 12),
               round(p.accuracy_penalty, 12))
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def test_skyline_frontier_matches_bruteforce():
    rng = np.random.default_rng(0)
    for trial in range(50):
        n = int(rng.integers(0, 40))
        grid = rng.integers(1, 4, size=(n, 3)).astype(float)  # many ties
        plans = [ScheduledPlan(((0, 1, f"p{i}"),), *row)
                 for i, row in enumerate(grid)]
        want = [(p.latency_s, p.energy_j, p.accuracy_penalty)
                for p in _brute_frontier(plans)]
        got = [(p.latency_s, p.energy_j, p.accuracy_penalty)
               for p in pareto_frontier(plans)]
        assert want == got


def test_reschedule_over_subset_excludes_lost():
    layers = _layers()
    names = ["mpsoc_dpu", "myriadx_vpu", "edge_tpu"]
    plans = reschedule_over_subset(layers, names, lost=["myriadx_vpu"])
    assert plans
    for p in plans:
        assert "myriadx_vpu" not in plan_profiles(p)
    assert reschedule_over_subset(layers, names, lost=names) == []
    # survivors-only equals a direct schedule over the survivors
    direct = schedule(layers, ["mpsoc_dpu", "edge_tpu"])
    assert ([p.assignments for p in plans]
            == [p.assignments for p in direct])


def test_price_assignments_monotone_in_batch():
    layers = _layers()
    plan = schedule(layers, ["mpsoc_dpu"])[0]
    l1, e1 = price_assignments(layers, plan, batch=1)
    l8, e8 = price_assignments(layers, plan, batch=8)
    assert l8 > l1 and e8 > e1
    assert l1 == pytest.approx(plan.latency_s)


# ---------------------------------------------------------------------------
# SLO admission
# ---------------------------------------------------------------------------
def test_admission_rejects_infeasible_latency_budget():
    layers = _layers()
    router = Router(layers, [_pool("a", ("mpsoc_dpu",), layers)])
    req = RouterRequest(0, SLOClass("impossible", max_latency_s=1e-9), 0.0)
    assert not router.submit(req, 0.0)
    assert router.telemetry.rejected == 1
    assert router.telemetry.admitted == 0


def test_admission_rejects_infeasible_accuracy_budget():
    layers = _layers()
    # both profiles carry a nonzero accuracy prior -> 0.005 is unmeetable
    router = Router(layers, [_pool("a", ("mpsoc_dpu", "myriadx_vpu"),
                                  layers)])
    bad = SLOClass("too-accurate", max_latency_s=30.0,
                   max_accuracy_penalty=0.005)
    assert not router.submit(RouterRequest(0, bad, 0.0), 0.0)
    assert router.submit(RouterRequest(1, RELAXED, 0.0), 0.0)


def test_admission_sheds_load_when_queues_hopeless():
    layers = _layers()
    router = Router(layers, [_pool("a", ("mpsoc_dpu",), layers,
                                   capacity=1, max_window=1)])
    tight = SLOClass("tight", max_latency_s=router.frontier[0].latency_s
                     * 1.5)
    admitted = sum(router.submit(RouterRequest(i, tight, 0.0), 0.0)
                   for i in range(10))
    assert 1 <= admitted < 10          # backlog estimate rejects the rest
    assert router.telemetry.rejected == 10 - admitted


def test_select_plan_nominal_policy():
    layers = _layers()
    plans = schedule(layers, ["mpsoc_dpu", "myriadx_vpu", "edge_tpu"])
    pick = select_plan(plans, RELAXED)
    assert pick is not None
    # cheapest energy among admissible plans
    assert pick.energy_j == min(p.energy_j for p in plans)
    assert select_plan(plans, SLOClass("no", max_latency_s=1e-12)) is None
    # headroom prefers a faster plan when the cheapest is deadline-tight
    tight = SLOClass("tight", max_latency_s=pick.latency_s * 1.05)
    slack_pick = select_plan(plans, tight, latency_headroom=0.5)
    assert slack_pick is not None
    assert (slack_pick.latency_s <= 0.5 * tight.max_latency_s
            or slack_pick is pick)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_least_loaded_routing_spreads_requests():
    layers = _layers()
    pools = [_pool("a", ("mpsoc_dpu",), layers),
             _pool("b", ("mpsoc_dpu",), layers)]
    router = Router(layers, pools)
    for i in range(4):
        assert router.submit(RouterRequest(i, RELAXED, 0.0), 0.0)
    assert pools[0].load == 2 and pools[1].load == 2


def test_pool_choice_uses_completion_estimate_not_raw_load():
    layers = _layers()
    a = _pool("a", ("mpsoc_dpu",), layers, capacity=1, max_window=1)
    b = _pool("b", ("mpsoc_dpu",), layers, capacity=4, max_window=4)
    router = Router(layers, [a, b])
    plan = router.frontier[0]
    for i in range(3):       # a: 3 queued serial batches (slow drain)
        a.enqueue(RouterRequest(100 + i, RELAXED, 0.0, plan=plan), 0.0)
    for i in range(4):       # b: nominally "more loaded" but one wave
        b.enqueue(RouterRequest(200 + i, RELAXED, 0.0, plan=plan), 0.0)
    slo = SLOClass("two-lat", max_latency_s=2.0 * plan.latency_s)
    req = RouterRequest(0, slo, 0.0)
    assert router.submit(req, 0.0)     # b's estimate fits, a's does not
    assert req.pool == "b"


def test_dispatch_selects_admissible_frontier_plan():
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    pools = [_pool("board", ("mpsoc_dpu", "myriadx_vpu"), layers),
             _pool("sidecar", ("edge_tpu",), layers)]
    router = Router(layers, pools)
    for slo in (RELAXED, ACC_TIGHT, CRITICAL):
        req = RouterRequest(0, slo, 0.0)
        assert router.submit(req, 0.0)
        assert req.plan in router.frontier
        assert slo.admits(req.plan)
        assert not any(q.dominates(req.plan) for q in router.frontier)


def test_urgent_requests_launch_without_window_fill():
    layers = _layers()
    pool = _pool("a", ("mpsoc_dpu",), layers, max_window=8,
                 max_wait_s=10.0)
    router = Router(layers, [pool])
    router.submit(RouterRequest(0, CRITICAL, 0.0), 0.0)
    router.step(1e-4)                  # long before max_wait_s
    assert pool.in_flight == 1
    router2 = Router(layers, [_pool("b", ("mpsoc_dpu",), layers,
                                    max_window=8, max_wait_s=10.0)])
    router2.submit(RouterRequest(0, RELAXED, 0.0), 0.0)
    router2.step(1e-4)
    assert router2.pools["b"].in_flight == 0   # still batching


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------
def _mixed_fleet(layers):
    return [_pool("pool-dpu", ("mpsoc_dpu",), layers),
            _pool("pool-vpu", ("myriadx_vpu",), layers),
            _pool("pool-tpu", ("edge_tpu",), layers)]


def test_failover_completes_all_inflight_requests():
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    router = Router(layers, _mixed_fleet(layers))
    fc = FailoverController(router, PoolFaultInjector(
        [PoolFault("pool-vpu", at_s=0.05, duration_s=math.inf)]))
    # ACC_TIGHT admits only VPU plans -> everything lands on pool-vpu
    reqs = [RouterRequest(i, ACC_TIGHT, 0.001 * i) for i in range(12)]
    _drive(router, fc, reqs)
    snap = router.telemetry.snapshot()
    assert snap["admitted"] == 12
    assert snap["completed"] == 12 and snap["dropped"] == 0
    assert snap["failovers"] == 1
    assert snap["pools"]["pool-vpu"]["evicted"] > 0
    for r in reqs:
        assert r.done_s is not None
        if r.rerouted:                 # displaced -> served by a survivor
            assert "myriadx_vpu" not in plan_profiles(r.plan)
    # the degraded profile is gone from the live frontier
    assert all("myriadx_vpu" not in plan_profiles(p)
               for p in router.frontier)
    assert router.pools["pool-vpu"].state is PoolState.DEAD


def test_transient_fault_recovers_frontier():
    layers = _layers()
    router = Router(layers, _mixed_fleet(layers))
    fc = FailoverController(router, PoolFaultInjector(
        [PoolFault("pool-vpu", at_s=0.01, duration_s=0.05)]))
    fc.poll(0.02)                      # degrade applied
    assert router.pools["pool-vpu"].state is PoolState.DEAD
    assert "myriadx_vpu" not in router.available_profiles()
    fc.poll(0.07)                      # scrub window over
    assert router.pools["pool-vpu"].state is PoolState.HEALTHY
    assert "myriadx_vpu" in router.available_profiles()
    assert any("myriadx_vpu" in plan_profiles(p) for p in router.frontier)


def test_total_loss_drops_and_reports():
    layers = _layers()
    router = Router(layers, [_pool("only", ("mpsoc_dpu",), layers)])
    fc = FailoverController(router, PoolFaultInjector(
        [PoolFault("only", at_s=0.0, duration_s=math.inf)]))
    assert router.submit(RouterRequest(0, RELAXED, 0.0), 0.0)
    fc.poll(0.01)
    snap = router.telemetry.snapshot()
    assert snap["dropped"] == 1 and snap["violations"] == 1
    assert router.outstanding == 0


def test_pool_fault_injector_orders_events():
    inj = PoolFaultInjector([PoolFault("b", at_s=2.0, duration_s=1.0),
                             PoolFault("a", at_s=1.0)])
    assert [e.fault.pool for e in inj.poll(1.5)] == ["a"]
    evs = inj.poll(10.0)
    assert [(e.kind, e.fault.pool) for e in evs] == [("degrade", "b"),
                                                     ("recover", "b")]
    assert inj.pending == 0


# ---------------------------------------------------------------------------
# bounded failover retries: backoff on the virtual clock + reason codes
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_schedule():
    p = RetryPolicy(max_attempts=4, backoff_s=0.05, multiplier=2.0,
                    max_backoff_s=0.15)
    assert p.delay_s(2) == pytest.approx(0.05)   # first backed-off attempt
    assert p.delay_s(3) == pytest.approx(0.10)   # doubles
    assert p.delay_s(4) == pytest.approx(0.15)   # capped


def _evict_everywhere(router, req):
    """Displace ``req`` wherever it landed (the failover controller's
    degrade step, by hand), leaving every pool healthy again."""
    displaced = [r for p in router.pools.values() for r in p.degrade(())]
    assert displaced == [req]
    for p in router.pools.values():
        p.recover(())


def test_redispatch_backoff_waits_on_virtual_clock():
    """The first redispatch is immediate; the second waits out the
    policy backoff on the router's clock — a flapping pool cannot spin
    the router hot — but the request stays owed work throughout."""
    layers = _layers()
    router = Router(layers, [_pool("a", ("mpsoc_dpu",), layers),
                             _pool("b", ("mpsoc_dpu",), layers)])
    router.default_retry = RetryPolicy(max_attempts=5, backoff_s=0.05)
    req = RouterRequest(0, RELAXED, 0.0)
    assert router.submit(req, 0.0)
    _evict_everywhere(router, req)
    router.redispatch(req, 0.1)                  # attempt 1: immediate
    assert req.rerouted == 1 and not req.dropped
    assert sum(p.load for p in router.pools.values()) == 1
    _evict_everywhere(router, req)
    router.redispatch(req, 0.2)                  # attempt 2: heaped
    assert all(p.load == 0 for p in router.pools.values())
    assert router.outstanding == 1               # owed, waiting out backoff
    router.step(0.249)                           # 0.2 + 0.05 not yet due
    assert all(p.load == 0 for p in router.pools.values())
    router.step(0.251)                           # due: re-placed
    assert sum(p.load for p in router.pools.values()) == 1
    assert router.telemetry.retries == 2
    assert not req.dropped


def test_retry_exhaustion_and_no_route_reason_codes():
    """Exceeding the attempt budget drops with ``retry_exhausted``; a
    redispatch with nothing routable anywhere drops with ``no_route`` —
    both visible in the snapshot's reason ledger."""
    layers = _layers()
    router = Router(layers, [_pool("only", ("mpsoc_dpu",), layers)])
    router.retry_policies["relaxed"] = RetryPolicy(max_attempts=1)
    r0 = RouterRequest(0, RELAXED, 0.0)
    r1 = RouterRequest(1, RELAXED, 0.0)
    assert router.submit(r0, 0.0) and router.submit(r1, 0.0)
    # r0: first redispatch lands (budget 1), second exhausts the budget
    [p.degrade(()) for p in router.pools.values()]
    [p.recover(()) for p in router.pools.values()]
    router.redispatch(r0, 0.01)
    assert not r0.dropped
    router.redispatch(r0, 0.02)
    assert r0.dropped
    # r1: the whole fleet is dead at its redispatch -> total loss
    router.pools["only"].degrade(())
    router.redispatch(r1, 0.03)
    assert r1.dropped
    snap = router.telemetry.snapshot()
    assert snap["drops_by_reason"]["retry_exhausted"] == 1
    assert snap["drops_by_reason"]["no_route"] == 1
    assert snap["dropped"] == 2 and snap["retries"] >= 2


def test_backed_off_retry_past_deadline_drops_when_policy_says():
    """give_up_past_deadline: a queued retry whose deadline elapsed
    while it waited drops with the ``deadline`` reason instead of being
    served best-effort."""
    layers = _layers()
    router = Router(layers, [_pool("only", ("mpsoc_dpu",), layers)])
    router.default_retry = RetryPolicy(max_attempts=5, backoff_s=10.0,
                                       give_up_past_deadline=True)
    tight = SLOClass("tight", max_latency_s=0.5)
    req = RouterRequest(0, tight, 0.0)
    assert router.submit(req, 0.0)
    _evict_everywhere(router, req)
    router.redispatch(req, 0.1)                  # attempt 1: immediate
    _evict_everywhere(router, req)
    router.redispatch(req, 0.2)                  # attempt 2: due at 10.2
    router.step(20.0)                            # deadline (0.5) long gone
    assert req.dropped
    snap = router.telemetry.snapshot()
    assert snap["drops_by_reason"]["deadline"] == 1


# ---------------------------------------------------------------------------
# property: dispatch never selects a Pareto-dominated plan
# ---------------------------------------------------------------------------
LAYER_TABLES = st.lists(
    st.tuples(st.floats(1e6, 1e10), st.floats(1e3, 1e7),
              st.floats(1e3, 1e6)),
    min_size=2, max_size=8)


@given(LAYER_TABLES, st.sampled_from(["none", "kill-vpu"]))
@settings(deadline=None, max_examples=20)
def test_dispatch_never_selects_dominated_plan(rows, fault):
    layers = [LayerCost(f"l{i}", m, w, a, a)
              for i, (m, w, a) in enumerate(rows)]
    router = Router(layers, _mixed_fleet(layers))
    if fault == "kill-vpu":
        FailoverController(router, PoolFaultInjector(
            [PoolFault("pool-vpu", at_s=0.0)])).poll(0.0)
    reference = schedule(layers, sorted(router.available_profiles()))
    for j, slo in enumerate((RELAXED, ACC_TIGHT, CRITICAL)):
        req = RouterRequest(j, slo, 0.0)
        if router.submit(req, 0.0):
            assert not any(q.dominates(req.plan) for q in reference)


# ---------------------------------------------------------------------------
# windowed baseline non-blocking step API
# ---------------------------------------------------------------------------
def test_server_step_interleaves_to_same_outputs():
    import jax

    from repro.models import transformer as T
    from repro.runtime.serve import Request, WindowedBaselineServer

    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]

    srv_a = WindowedBaselineServer(params, cfg, max_batch=2, prompt_len=8,
                                   max_len=16)
    srv_b = WindowedBaselineServer(params, cfg, max_batch=2, prompt_len=8,
                                   max_len=16)
    for i, p in enumerate(prompts):
        srv_a.submit(Request(i, p, max_new=3))
        srv_b.submit(Request(i, p, max_new=3))
    while srv_a.pending:               # one decode step at a time
        srv_a.step()
    done_b = srv_b.flush() + srv_b.flush()
    assert len(done_b) == 3 and len(srv_a.done) == 3
    for i in range(3):
        np.testing.assert_array_equal(srv_a.done[i].output,
                                      srv_b.done[i].output)
