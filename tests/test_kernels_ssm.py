"""Shape/dtype sweeps for the fused SSM kernels (selective scan, wkv)
vs their jnp oracles — and agreement with the model-level mixers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(7)


def _scan_inputs(b, s, d, n, dtype):
    x = jnp.asarray(RNG.normal(size=(b, s, d))).astype(dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, d))) * 0.1).astype(dtype)
    bb = jnp.asarray(RNG.normal(size=(b, s, n))).astype(dtype)
    cc = jnp.asarray(RNG.normal(size=(b, s, n))).astype(dtype)
    a = jnp.asarray(-np.abs(RNG.normal(size=(d, n)))).astype(jnp.float32)
    dd = jnp.asarray(RNG.normal(size=(d,))).astype(jnp.float32)
    return x, dt, bb, cc, a, dd


@pytest.mark.parametrize("b,s,d,n,bd,q", [
    (2, 64, 32, 4, 16, 32),       # multi-block d + multi-chunk s
    (1, 37, 48, 8, 48, 8),        # ragged seq (pad path)
    (2, 16, 24, 16, 8, 16),       # d not multiple of default block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_matches_ref(b, s, d, n, bd, q, dtype):
    x, dt, bb, cc, a, dd = _scan_inputs(b, s, d, n, dtype)
    out = ops.selective_scan(x, dt, bb, cc, a, dd, bd=bd, q=q)
    ref = ops.selective_scan_ref(x, dt, bb, cc, a, dd)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_selective_scan_state_carries_across_chunks():
    """Chunk size must not change the result (state handoff exactness)."""
    x, dt, bb, cc, a, dd = _scan_inputs(1, 32, 16, 4, jnp.float32)
    o1 = ops.selective_scan(x, dt, bb, cc, a, dd, bd=16, q=4)
    o2 = ops.selective_scan(x, dt, bb, cc, a, dd, bd=16, q=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_selective_scan_matches_mamba_mixer_core():
    """The kernel computes the same recurrence the XLA mamba path uses."""
    from repro.models.mamba import _chunk_scan
    b, s, d, n = 1, 16, 8, 4
    x, dt, bb, cc, a, dd = _scan_inputs(b, s, d, n, jnp.float32)
    y_kernel = ops.selective_scan(x, dt, bb, cc, a, dd, bd=8, q=8)
    # XLA associative-scan equivalent
    a_bar = jnp.exp(dt[..., None] * a)
    bx = (dt * x)[..., None] * bb[:, :, None, :]
    hs, _ = _chunk_scan(jnp.zeros((b, d, n)), a_bar, bx)
    y_ref = jnp.einsum("bqdn,bqn->bqd", hs, cc) + dd * x
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=1e-4)


@pytest.mark.parametrize("b,s,h,n,q", [
    (2, 64, 2, 8, 16),
    (1, 21, 3, 16, 7),            # ragged seq
    (2, 32, 1, 64, 32),           # full head size
])
def test_wkv_matches_ref(b, s, h, n, q):
    mk = lambda: jnp.asarray(RNG.normal(size=(b, s, h, n)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(np.exp(-np.exp(RNG.normal(size=(b, s, h, n)) - 1))
                    .astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, n)).astype(np.float32))
    out = ops.wkv(r, k, v, w, u, q=q)
    ref = ops.wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_wkv_matches_rwkv_chunk_scan():
    from repro.models.rwkv import _wkv_chunk_scan
    b, s, h, n = 1, 24, 2, 8
    mk = lambda: jnp.asarray(RNG.normal(size=(b, s, h, n)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(np.exp(-np.exp(RNG.normal(size=(b, s, h, n))))
                    .astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, n)).astype(np.float32))
    y_kernel = ops.wkv(r, k, v, w, u, q=8)
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    y_xla, _ = _wkv_chunk_scan(s0, w, k, v, r, u, chunk=6)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_xla),
                               atol=1e-4)
