"""Pod-scale disaggregation: the PrefillHandoff wire format (versioned,
checksummed, bit-exact), the N-way sharded CoProcServer (least-loaded
import, per-shard backpressure, exactly-once corrupt-wire replay,
mid-run shard retirement with zero dropped streams), PoolSpec plumbing,
and the stage-axis executor's equivalence to the monolithic forward."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.runtime.serve import (ContinuousBatchingEngine, CoProcServer,
                                 HandoffCorruptError, HandoffWireError,
                                 PrefillHandoff, Request, WIRE_VERSION)

from conftest import tiny_dense

PROMPT_LEN, MAX_LEN, BLOCK = 8, 48, 4


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# wire format: round-trip, truncation, version, corruption
# ---------------------------------------------------------------------------
def _synthetic_handoff(dtype, n_blocks, rid=7, digests=None, seed=0):
    """A handoff with engine-shaped KV ([n_super, n_blocks, P, KVp, hd])
    but synthetic contents — wire tests need arbitrary dtypes/shapes,
    not a live engine."""
    rng = np.random.default_rng(seed)
    kv = {}
    for key in ("blk0.attn", "blk1.attn"):
        k = rng.standard_normal((2, n_blocks, BLOCK, 2, 4)).astype(dtype)
        v = rng.standard_normal((2, n_blocks, BLOCK, 2, 4)).astype(dtype)
        kv[key] = (k, v)
    return PrefillHandoff(rid=rid, first_token=42,
                          length=n_blocks * BLOCK, block_size=BLOCK,
                          kv=kv, digests=digests)


def _np(x):
    return np.asarray(x)


@pytest.mark.parametrize("dtype", ["float32", "float16", "bfloat16"])
@pytest.mark.parametrize("n_blocks", [1, 3, 7])
def test_wire_roundtrip_bit_exact(dtype, n_blocks):
    import ml_dtypes
    dt = (np.dtype(getattr(ml_dtypes, dtype)) if dtype == "bfloat16"
          else np.dtype(dtype))
    ho = _synthetic_handoff(dt, n_blocks,
                            digests=(0, 1, 2**31, 0xFFFFFFFF))
    wire = ho.to_bytes()
    back = PrefillHandoff.from_bytes(wire)
    assert (back.rid, back.first_token, back.length, back.block_size) \
        == (ho.rid, ho.first_token, ho.length, ho.block_size)
    assert back.digests == ho.digests
    assert sorted(back.kv) == sorted(ho.kv)
    for key in ho.kv:
        for a, b in zip(ho.kv[key], back.kv[key]):
            assert _np(b).dtype == _np(a).dtype
            assert _np(b).shape == _np(a).shape
            assert _np(b).tobytes() == _np(a).tobytes()   # bit-exact
    # serializing the parse yields the identical frame
    assert back.to_bytes() == wire


def test_wire_roundtrip_none_digests():
    ho = _synthetic_handoff(np.float32, 2, digests=None)
    back = PrefillHandoff.from_bytes(ho.to_bytes())
    assert back.digests is None
    assert back.to_bytes() == ho.to_bytes()


def test_wire_rejects_truncation_at_every_boundary():
    wire = _synthetic_handoff(np.float32, 2).to_bytes()
    cuts = [0, 3, 10, 17, len(wire) // 2, len(wire) - 1]
    for cut in cuts:
        with pytest.raises(HandoffWireError):
            PrefillHandoff.from_bytes(wire[:cut])


def test_wire_rejects_version_mismatch_and_bad_magic():
    import struct
    wire = bytearray(_synthetic_handoff(np.float32, 1).to_bytes())
    struct.pack_into("<H", wire, 4, WIRE_VERSION + 1)
    with pytest.raises(HandoffWireError, match="version"):
        PrefillHandoff.from_bytes(bytes(wire))
    wire = b"NOPE" + _synthetic_handoff(np.float32, 1).to_bytes()[4:]
    with pytest.raises(HandoffWireError, match="magic"):
        PrefillHandoff.from_bytes(wire)


def test_wire_payload_flip_is_corruption_not_wire_error():
    """An in-transit bit upset on an intact frame is the *retryable*
    failure (the seam re-requests); every payload byte is covered."""
    wire = _synthetic_handoff(np.float32, 2).to_bytes()
    for pos in (18, len(wire) // 2, len(wire) - 1):
        bad = wire[:pos] + bytes([wire[pos] ^ 0x10]) + wire[pos + 1:]
        with pytest.raises(HandoffCorruptError):
            PrefillHandoff.from_bytes(bad)


# ---------------------------------------------------------------------------
# sharded CoProcServer: fan-out, churn, exactly-once replay
# ---------------------------------------------------------------------------
def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("prompt_len", PROMPT_LEN)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", BLOCK)
    return ContinuousBatchingEngine(params, cfg, **kw)


def _sharded(model, n_shards, decode_blocks=None):
    pre = _engine(model, max_slots=1)
    decs = [_engine(model, max_slots=2, num_blocks=decode_blocks)
            for _ in range(n_shards)]
    return CoProcServer(pre, decs)


def _workload(n=6, seed=21):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, 256, int(rng.integers(10, 33))
                             ).astype(np.int32), int(rng.integers(2, 7)))
            for i in range(n)]


def _drain(srv):
    steps = 0
    while srv.pending:
        srv.step()
        steps += 1
        assert steps < 1000, "server failed to make progress"


@pytest.fixture(scope="module")
def unified_reference(model):
    uni = _engine(model, max_slots=4)
    for rid, p, mn in _workload():
        uni.submit(Request(rid, p, max_new=mn))
    _drain(uni)
    return {rid: uni.done[rid].output for rid, _, _ in _workload()}


def test_two_shard_outputs_bit_identical_to_unified(model,
                                                    unified_reference):
    co = _sharded(model, 2)
    for rid, p, mn in _workload():
        co.submit(Request(rid, p, max_new=mn))
    _drain(co)
    for rid, _, mn in _workload():
        out = co.done[rid].output
        assert out.shape == (mn,)
        np.testing.assert_array_equal(out, unified_reference[rid])
    st = co.stats()
    assert st["decode_shards"] == 2
    assert st["handoffs"] == 6
    # least-loaded fan-out actually spread the imports
    assert set(st["imports_by_shard"]) == {"shard0", "shard1"}
    assert all(v > 0 for v in st["imports_by_shard"].values())
    assert sum(st["imports_by_shard"].values()) == 6


def test_single_shard_coproc_unchanged(model, unified_reference):
    """N=1 is the classic co-processing split — same outputs, same
    stats surface (plus the shard keys)."""
    co = _sharded(model, 1)
    for rid, p, mn in _workload():
        co.submit(Request(rid, p, max_new=mn))
    _drain(co)
    for rid, _, _ in _workload():
        np.testing.assert_array_equal(co.done[rid].output,
                                      unified_reference[rid])
    assert co.stats()["imports_by_shard"] == {"shard0": 6}


def test_shard_retires_mid_run_with_zero_dropped_streams(
        model, unified_reference):
    """Retiring a decode shard while its streams are mid-decode drains
    them in place; new handoffs fan out over the survivors; every
    stream completes bit-identically."""
    co = _sharded(model, 2)
    emitted = []
    co.on_token = lambda rid, tok: emitted.append((rid, tok))
    for rid, p, mn in _workload():
        co.submit(Request(rid, p, max_new=mn))
    for _ in range(3):                    # both shards hold live streams
        co.step()
    assert co.stats()["imports_by_shard"]["shard1"] > 0
    frozen = co.stats()["imports_by_shard"]["shard1"]
    co.retire_shard(1)
    _drain(co)
    assert len(co.done) == 6
    for rid, _, mn in _workload():
        np.testing.assert_array_equal(co.done[rid].output,
                                      unified_reference[rid])
        stream = [t for r, t in emitted if r == rid]
        np.testing.assert_array_equal(stream, co.done[rid].output)
    # no NEW imports landed on the draining shard
    assert co.stats()["imports_by_shard"]["shard1"] == frozen
    # the last live shard can never retire; bad indices fail loudly
    with pytest.raises(ValueError):
        co.retire_shard(0)
    with pytest.raises(IndexError):
        co.retire_shard(5)


def test_corrupt_wire_replayed_exactly_once(model, unified_reference):
    """An armed in-transit upset on one handoff: the seam re-requests
    it exactly once, outputs stay bit-identical, first token is never
    double-streamed."""
    co = _sharded(model, 2)
    emitted = []
    co.on_token = lambda rid, tok: emitted.append((rid, tok))
    co.inject_handoff_corruption()
    for rid, p, mn in _workload():
        co.submit(Request(rid, p, max_new=mn))
    _drain(co)
    st = co.stats()
    assert st["handoffs_replayed"] == 1
    for rid, _, mn in _workload():
        np.testing.assert_array_equal(co.done[rid].output,
                                      unified_reference[rid])
        assert len([t for r, t in emitted if r == rid]) == mn


def test_per_shard_seam_backpressure(model):
    """Decode pools sized for one request each: the seam defers at the
    full shard, tries the other, and completes everything without
    re-prefilling."""
    co = _sharded(model, 2, decode_blocks=MAX_LEN // BLOCK)
    reqs = _workload(4, seed=5)
    for rid, p, mn in reqs:
        co.submit(Request(rid, p, max_new=mn))
    _drain(co)
    assert len(co.done) == 4
    st = co.stats()
    # prefill ran once per request (prompts pad to the chunk grid ==
    # the prompt_len bucket): deferral parks the wire frame, it never
    # burns the prefill again
    assert st["prefill_tokens"] == sum(
        -(-len(p) // PROMPT_LEN) * PROMPT_LEN for _, p, _ in reqs)
    for eng in co.decodes:
        assert eng.alloc.available == eng.alloc.num_blocks


# ---------------------------------------------------------------------------
# PoolSpec plumbing
# ---------------------------------------------------------------------------
def test_poolspec_decode_shards_json_roundtrip():
    from repro.serving import PoolSpec
    ps = PoolSpec("pod", ("tpu_v5e_bf16",), backend="engine",
                  prefill_backend="engine", decode_shards=3).validate()
    back = PoolSpec.from_dict(ps.to_dict()).validate()
    assert back.decode_shards == 3
    assert back == ps


def test_poolspec_sharding_validation():
    from repro.serving import PoolSpec
    with pytest.raises(ValueError, match="decode_shards"):
        PoolSpec("p", ("tpu_v5e_bf16",), backend="engine",
                 prefill_backend="engine", decode_shards=0).validate()
    with pytest.raises(ValueError, match="prefill_backend"):
        PoolSpec("p", ("tpu_v5e_bf16",), backend="engine",
                 decode_shards=2).validate()
    with pytest.raises(ValueError, match="pipeline_stages"):
        PoolSpec("p", ("tpu_v5e_bf16",), backend="engine",
                 pipeline_stages=1).validate()
    with pytest.raises(ValueError, match="mutually exclusive"):
        PoolSpec("p", ("tpu_v5e_bf16",), backend="engine",
                 prefill_backend="engine", pipeline_stages=2).validate()


# ---------------------------------------------------------------------------
# stage-axis executor: pipeline decode == monolithic forward
# ---------------------------------------------------------------------------
ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
       "PYTHONPATH": "src"}


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_stage_axis_engine_matches_monolithic_forward():
    out = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.runtime.serve import Request
        from repro.serving.stage_executor import StageAxisEngine

        cfg = get_config("qwen3-14b", smoke=True).with_(num_layers=4,
                                                        remat=False)
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        eng = StageAxisEngine(params, cfg, num_stages=2, max_slots=2,
                              prompt_len=8, max_len=12)
        rng = np.random.default_rng(4)
        reqs = [(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32))
                for i, n in enumerate((3, 6, 8))]
        for rid, p in reqs:
            eng.submit(Request(rid, p, max_new=4))
        while eng.pending:
            eng.step()

        def mono_greedy(prompt, max_new):
            seq = list(map(int, prompt))
            for _ in range(max_new):
                S = len(seq)
                toks = np.zeros((1, 12), np.int32)
                toks[0, :S] = seq
                logits = T.forward(params, cfg, jnp.asarray(toks),
                                   plan=eng.plan).logits
                seq.append(int(jnp.argmax(logits[0, S - 1])))
            return seq[len(prompt):]

        for rid, p in reqs:
            got = list(eng.done[rid].output)
            assert got == mono_greedy(p, 4), (rid, got)
            print("ok")
        st = eng.stats()
        assert st["total_tokens"] == 12 and st["num_stages"] == 2
        print("ok")
    """)
    assert out.count("ok") == 4


def test_stage_axis_pool_via_fleetspec_facade():
    out = _run("""
        import numpy as np
        import jax
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.serving import FleetSpec, PoolSpec

        cfg = get_config("qwen3-14b", smoke=True).with_(num_layers=4,
                                                        remat=False)
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        spec = FleetSpec(
            pools=[PoolSpec("staged", ("tpu_v5e_bf16",), backend="engine",
                            capacity=1, max_window=4, max_wait_s=0.0,
                            max_slots=2, prompt_len=8, max_new=4,
                            pipeline_stages=2)],
            workload="transformer", arch="qwen3-14b", seq_len=8)
        client = spec.build(model=(cfg, params))
        rng = np.random.default_rng(7)
        hs = [client.submit(rng.integers(0, cfg.vocab_size, 5)
                            .astype(np.int32), slo="offline", max_new=3)
              for _ in range(3)]
        client.drain()
        for h in hs:
            assert len(h.result().tokens) == 3
            print("ok")
    """)
    assert out.count("ok") == 3
