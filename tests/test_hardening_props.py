"""Property tests for the hardened KV-block accounting.

The claim the recovery path leans on: *no interleaving* of allocation,
prefix sharing, release, and corruption-quarantine can make the
allocator's books drift — ``free + live + quarantined == num_blocks``
exactly, a quarantined block never re-enters the free list, and
:class:`SharedBlockIndex` refcounts cannot leak through a ``purge``
(every surviving holder's eventual release treats the purged block as
untracked and the allocator skips it).

Runs both ways: ``hypothesis``-driven interleavings when the library is
installed, and a deterministic seeded sweep of the same state machine
either way (so the invariant is exercised even where only the conftest
stub is available).
"""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.paging import (BlockAllocator, OutOfBlocksError,
                                  SharedBlockIndex)

N_BLOCKS = 12
OPS = ("prefill", "share", "finish", "corrupt")


def _check_books(alloc, shared, seqs):
    """The exact-accounting invariant, after every single op."""
    free = alloc.free
    assert len(set(free)) == len(free)                  # no double-free
    assert not set(free) & alloc.quarantined            # disjoint lanes
    held = {b for blocks in seqs.values() for b in blocks}
    # live is derived; it must equal the blocks sequences actually hold
    # minus the ones pulled into quarantine out from under them
    assert alloc.live == len(held - alloc.quarantined)
    assert alloc.available + alloc.live + len(alloc.quarantined) \
        == alloc.num_blocks
    for b, refs in shared._refs.items():
        assert refs > 0                                 # no zombie entries
        assert b not in alloc.quarantined               # purged on corrupt
        assert shared._digest_of[b] in shared._by_digest


def _drive(ops):
    """One interleaving: sequences prefill (fresh block + digest),
    share (acquire an existing digest), finish (release through the
    shared index), and random corruption (purge + quarantine)."""
    alloc = BlockAllocator(N_BLOCKS)
    shared = SharedBlockIndex(alloc)
    seqs = {}                        # seq id -> [blocks held]
    digests = []                     # published digests, for sharers
    next_seq = 0
    for code, arg in ops:
        op = OPS[code % len(OPS)]
        if op == "prefill":
            try:
                blk = alloc.alloc()
            except OutOfBlocksError:
                continue
            dig = SharedBlockIndex.chain(
                SharedBlockIndex.ROOT, np.array([arg, next_seq], np.int32))
            shared.register(dig, blk)
            digests.append(dig)
            seqs[next_seq] = [blk]
            next_seq += 1
        elif op == "share" and digests:
            blk = shared.acquire(digests[arg % len(digests)])
            if blk is not None:
                seqs[next_seq] = [blk]
                next_seq += 1
        elif op == "finish" and seqs:
            sid = sorted(seqs)[arg % len(seqs)]
            # untracked blocks (purged, or never shared) come back to
            # the caller, who returns them to the allocator — the
            # engine's teardown path verbatim
            alloc.release(shared.release(seqs.pop(sid)))
        elif op == "corrupt" and shared._refs:
            blk = sorted(shared._refs)[arg % len(shared._refs)]
            shared.purge(blk)
            assert alloc.quarantine(blk)
        _check_books(alloc, shared, seqs)
    for sid in sorted(seqs):                           # drain everything
        alloc.release(shared.release(seqs.pop(sid)))
        _check_books(alloc, shared, seqs)
    # final books: every non-quarantined block is home, the index empty
    assert alloc.available + len(alloc.quarantined) == N_BLOCKS
    assert not shared._refs and not shared._by_digest
    return alloc


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 10**6)),
                max_size=60))
@settings(deadline=None, max_examples=60)
def test_accounting_exact_under_random_interleavings(ops):
    _drive(ops)


def test_accounting_exact_seeded_sweep():
    """The same machine under a deterministic sweep — runs even where
    hypothesis is only stubbed."""
    quarantined_somewhere = False
    for seed in range(25):
        rng = random.Random(seed)
        ops = [(rng.randrange(4), rng.randrange(10**6))
               for _ in range(rng.randrange(10, 60))]
        alloc = _drive(ops)
        quarantined_somewhere |= bool(alloc.quarantined)
    assert quarantined_somewhere     # the sweep really exercised corrupt


def test_quarantine_of_free_block_removes_it_from_service():
    alloc = BlockAllocator(4)
    assert alloc.quarantine(2)       # upset caught while the block idles
    assert not alloc.quarantine(2)   # idempotent
    assert alloc.available == 3 and 2 not in alloc.free
    got = {alloc.alloc() for _ in range(3)}
    assert 2 not in got
    with pytest.raises(OutOfBlocksError):
        alloc.alloc()                # quarantined lane never comes back
    alloc.release(list(got) + [2])   # release of a quarantined block: no-op
    assert alloc.available == 3 and alloc.live == 0


def test_release_hook_fires_once_per_homed_block():
    alloc = BlockAllocator(4)
    homed = []
    alloc.on_release = homed.append
    a, b = alloc.alloc(), alloc.alloc()
    alloc.quarantine(b)
    alloc.release([a, b, -1])        # trash rows and quarantine skipped
    assert homed == [a]
