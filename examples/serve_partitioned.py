"""Batched serving with an MPAI-partitioned model: int8 backbone + bf16
head, request queue with bounded batching windows, prefill + greedy decode
against a KV cache.

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.core import qat
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.runtime.serve import BatchingServer, Request


def main():
    cfg = get_config("qwen3-14b", smoke=True).with_(num_layers=4,
                                                    remat=False)
    params = T.model_init(jax.random.PRNGKey(0), cfg)

    plan = qat.serve_plan(PartitionPlan.mpai(cfg.num_layers, split=3))
    print(f"serving {cfg.name}: segments="
          f"{[(s.name, s.policy.precision.value, s.policy.mode) for s in plan.segments]}")

    srv = BatchingServer(params, cfg, plan=plan, max_batch=4,
                         prompt_len=16, max_len=32)
    rng = np.random.default_rng(0)
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              rng.integers(3, 16)).astype(np.int32)
        srv.submit(Request(i, prompt, max_new=8))

    window = 0
    while srv.queue:
        done = srv.flush()
        window += 1
        print(f"window {window}: served {len(done)} requests "
              f"({len(srv.queue)} queued)")
    for rid in sorted(srv.done):
        r = srv.done[rid]
        print(f"  req {rid:2d}: prompt[{r.prompt.shape[0]:2d} tok] -> "
              f"{r.output.tolist()}")
    print("bounded batching window = straggler mitigation at serve time: "
          "no request waits more than one flush.")


if __name__ == "__main__":
    main()
