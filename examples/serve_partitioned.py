"""Pod-scale disaggregated serving through the fleet facade: an
MPAI-partitioned model with a dedicated prefill stage (the DPU
analogue) fanning handoffs out to TWO decode shards over the versioned
wire format, streaming responses end to end.

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import FleetSpec, PoolSpec


def main():
    # 4 layers so the mpai split=3 leaves a 3-layer int8 backbone ahead
    # of the high-precision tail, like the paper's UrsoNet deployment
    cfg = get_config("qwen3-14b", smoke=True).with_(num_layers=4,
                                                    remat=False)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    # one pool, three engines: a prefill stage feeding 2 decode shards.
    # Every handoff crosses the seam as serialized bytes (versioned +
    # checksummed) and lands on the least-loaded live shard.
    spec = FleetSpec(
        pools=[PoolSpec("board", ("tpu_v5e_int8", "tpu_v5e_bf16"),
                        backend="engine", capacity=1, max_window=4,
                        max_wait_s=0.0, max_slots=4, prompt_len=16,
                        max_new=8, plan="mpai", plan_split=3,
                        prefill_backend="engine", decode_shards=2)],
        workload="transformer", arch="qwen3-14b", seq_len=16)
    client = spec.build(model=(cfg, params))
    server = client.engines["board"]
    plan = server.decode.plan
    print(f"serving {server.decode.cfg.name}: "
          f"1 prefill stage -> {server.decode_shards} decode shards, "
          f"segments={[(s.name, s.policy.precision.value, s.policy.mode) for s in plan.segments]}")

    rng = np.random.default_rng(0)
    handles = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              rng.integers(3, 16)).astype(np.int32)
        handles.append(client.submit(prompt, slo="offline", max_new=8))

    # stream the first response token-by-token while the rest decode in
    # the same slots (continuous batching: no window to wait out)
    print(f"  req 0 streams: {list(handles[0].stream())}")
    client.drain()
    for h in handles:
        r = h.result()
        print(f"  req {r.rid:2d}: prompt -> {r.tokens.tolist()}")
    pool = client.telemetry["pools"]["board"]
    pre = client.telemetry["pools"]["board.prefill"]
    print(f"disaggregated serving: {pool['tokens_generated']} tokens, "
          f"{pool['decode_tokens_per_s']:.0f} decode tok/s, occupancy "
          f"p50 {pool['slot_occupancy']['p50']}")
    print(f"  seam: {pre['prefill_tokens']} tokens prefilled, handoff "
          f"imports by shard {pool['imports_by_shard']}, prefix hit "
          f"rate {pool['prefix_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
