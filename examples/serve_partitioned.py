"""Batched serving with an MPAI-partitioned model through the serving
facade: int8 backbone + bf16 head, continuous-batching decode over the
paged KV pool, streaming responses.

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import FleetSpec, PoolSpec


def main():
    # 4 layers so the mpai split=3 leaves a 3-layer int8 backbone ahead
    # of the high-precision tail, like the paper's UrsoNet deployment
    cfg = get_config("qwen3-14b", smoke=True).with_(num_layers=4,
                                                    remat=False)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    spec = FleetSpec(
        pools=[PoolSpec("board", ("tpu_v5e_int8", "tpu_v5e_bf16"),
                        backend="engine", capacity=1, max_window=4,
                        max_wait_s=0.0, max_slots=4, prompt_len=16,
                        max_new=8, plan="mpai", plan_split=3)],
        workload="transformer", arch="qwen3-14b", seq_len=16)
    client = spec.build(model=(cfg, params))
    engine = client.engines["board"]
    plan = engine.plan
    print(f"serving {engine.cfg.name}: segments="
          f"{[(s.name, s.policy.precision.value, s.policy.mode) for s in plan.segments]}")

    rng = np.random.default_rng(0)
    handles = []
    for i in range(10):
        prompt = rng.integers(0, engine.cfg.vocab_size,
                              rng.integers(3, 16)).astype(np.int32)
        handles.append(client.submit(prompt, slo="offline", max_new=8))

    # stream the first response token-by-token while the rest decode in
    # the same slots (continuous batching: no window to wait out)
    print(f"  req 0 streams: {list(handles[0].stream())}")
    client.drain()
    for h in handles:
        r = h.result()
        print(f"  req {r.rid:2d}: prompt -> {r.tokens.tolist()}")
    pool = client.telemetry["pools"]["board"]
    print(f"slot-continuous serving: {pool['tokens_generated']} tokens, "
          f"{pool['decode_tokens_per_s']:.0f} decode tok/s, occupancy "
          f"p50 {pool['slot_occupancy']['p50']} — no request waits for a "
          f"window to drain.")


if __name__ == "__main__":
    main()
