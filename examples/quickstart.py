"""Quickstart: the MPAI lifecycle on a small LM, end to end on CPU.

  1. pick an architecture config (+ reduced size),
  2. let the MPAI scheduler choose a partition (int8 backbone / bf16 head),
  3. partition-aware training (QAT) with the distributed Trainer,
  4. deploy: convert the plan to real-int8 serving and compare perplexity
     against the bf16 baseline and a PTQ (no-QAT) deployment,
  5. serve the trained model through the ``repro.serving`` facade:
     FleetSpec -> ServingClient -> streamed tokens.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.core import qat
from repro.core.cost_model import transformer_layer_costs
from repro.core.partition import PartitionPlan
from repro.core.scheduler import best_under_accuracy, schedule
from repro.data.pipeline import lm_batch
from repro.models import transformer as T
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # 1. reduced config of the chosen architecture family
    cfg = get_config(args.arch, smoke=True).with_(
        num_layers=4, d_model=128, d_ff=256, remat=False)
    shape = ShapeConfig("quickstart", 64, 8, "train")
    print(f"arch={cfg.name}  params~{cfg.param_count()/1e6:.1f}M")

    # 2. MPAI scheduler: pick the partition from the cost model
    layers = transformer_layer_costs(cfg, shape.seq_len)
    plans = schedule(layers, ["tpu_v5e_int8", "tpu_v5e_bf16"],
                     accuracy_penalty={"tpu_v5e_int8": 0.05})
    chosen = best_under_accuracy(plans, max_penalty=0.045)
    print("scheduler chose:", chosen.assignments,
          f"-> {chosen.latency_s*1e3:.2f} ms model latency")
    plan = chosen.to_partition_plan(qat=True)

    # 3. partition-aware training
    mesh_cfg = MeshConfig((1, 1), ("data", "model"))
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                     total_steps=args.steps)
    trainer = Trainer(cfg, shape, mesh_cfg, tc, plan=qat.train_plan(plan))
    state = trainer.init_state()
    state, hist = trainer.run(state, lambda s: lm_batch(cfg, shape, s),
                              args.steps, log_every=max(args.steps // 8, 1))
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.3f}")

    # 4. deployment comparison on held-out batches
    def eval_loss(plan_):
        tot = 0.0
        for s in range(3):
            b = lm_batch(cfg, shape, 10_000 + s)
            tot += float(T.loss_fn(state.params, cfg, b["tokens"],
                                   b["labels"], plan_))
        return tot / 3

    bf16 = eval_loss(None)
    mpai = eval_loss(qat.serve_plan(plan))
    ptq = eval_loss(PartitionPlan.int8_all(cfg.num_layers))
    print(f"\neval loss  bf16={bf16:.4f}  MPAI-int8(QAT)={mpai:.4f}  "
          f"PTQ-int8={ptq:.4f}")
    print("MPAI deployment keeps the backbone int8 (2x MXU rate, half the "
          "weight bytes) at near-baseline loss; PTQ shows the gap QAT closes.")

    # 5. one front door over the fleet: declare a pool, submit, stream
    from repro.serving import FleetSpec, PoolSpec
    fleet = FleetSpec(
        pools=[PoolSpec("deploy", ("tpu_v5e_int8", "tpu_v5e_bf16"),
                        backend="engine", capacity=1, max_wait_s=0.0,
                        max_slots=2, prompt_len=8, max_new=8)],
        workload="transformer", arch=args.arch, seq_len=shape.seq_len)
    client = fleet.build(model=(cfg, state.params))
    prompt = lm_batch(cfg, shape, 42)["tokens"][0, :8]
    handle = client.submit(jnp.asarray(prompt), slo="offline", max_new=8)
    print("served through repro.serving:", list(handle.stream()))


if __name__ == "__main__":
    main()
