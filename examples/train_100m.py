"""End-to-end training driver: a ~100M-parameter qwen3-family model for a
few hundred steps, with MPAI QAT, checkpointing, and fault-tolerant
restart — the full production loop at local scale.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    # CPU note: ~100M params x 65k tokens/step is slow on one core; use
    # --dmodel 256 --seq 128 for a quick pass.
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.checkpoint.manager import CheckpointManager
from repro.core import qat
from repro.core.partition import PartitionPlan
from repro.data.pipeline import lm_batch
from repro.runtime.fault import FaultInjector, FaultTolerantRunner
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dmodel", type=int, default=640)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=50304)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--inject-fault", action="store_true",
                    help="kill step 25 and prove checkpoint/restart works")
    args = ap.parse_args()

    cfg = get_config("qwen3-14b", smoke=True).with_(
        name="qwen3-100m", num_layers=args.layers, d_model=args.dmodel,
        num_heads=args.dmodel // 64, num_kv_heads=args.dmodel // 128,
        head_dim=64, d_ff=args.dmodel * 3, vocab_size=args.vocab, remat=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tc = TrainConfig(learning_rate=6e-4, warmup_steps=30,
                     total_steps=args.steps, checkpoint_every=25)
    plan = qat.train_plan(PartitionPlan.mpai(cfg.num_layers))
    trainer = Trainer(cfg, shape, MeshConfig((1, 1), ("data", "model")), tc,
                      plan=plan)
    state = trainer.init_state()

    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="ckpt_100m_"), keep=3)
    runner = FaultTolerantRunner(trainer, ckpt, max_restarts=3)
    on_step = (FaultInjector(fail_at_steps={25}) if args.inject_fault
               else None)

    def log_data(s):
        return lm_batch(cfg, shape, s)

    state, hist = runner.run(state, log_data, args.steps, on_step=on_step,
                             log_every=max(args.steps // 15, 1))
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.3f}  "
              f"gnorm {h['grad_norm']:.2f}")
    if args.inject_fault:
        print(f"restarts: {runner.restarts} (fault injected at step 25, "
              f"recovered from checkpoint)")
    print(f"done: {int(state.step)} steps, final loss {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
