"""Minimal fleet-router example: three pools, one SEU, zero lost requests.

    PYTHONPATH=src python examples/route_failover.py

Routes 60 mixed-SLO UrsoNet inferences across two DPU+VPU boards and an
EdgeTPU sidecar — five lines of fleet spec, one submission interface.
The sidecar is the energy-optimal operating point, so the router loads
it up; at t=0.5s it takes a transient SEU and its queued and in-flight
requests are rescheduled onto the (dearer) boards.  Every admitted
request completes — deadline misses are *reported*, not dropped.
"""
from repro.router import SLO_CLASSES
from repro.serving import FaultSpec, FleetSpec, PoolSpec, open_loop

spec = FleetSpec(
    pools=[PoolSpec("board-a", ("mpsoc_dpu", "myriadx_vpu"), capacity=2),
           PoolSpec("board-b", ("mpsoc_dpu", "myriadx_vpu"), capacity=2),
           PoolSpec("sidecar", ("edge_tpu", "cortex_a53"), capacity=1)],
    workload="ursonet",
    accuracy_penalty={"mpsoc_dpu": 0.05},
    faults=[FaultSpec("sidecar", at_s=0.5, duration_s=1.0)])
client = spec.build()

classes = list(SLO_CLASSES.values())
weights = [1.0 / len(classes)] * len(classes)
handles = open_loop(client, classes, weights, rate_hz=30.0,
                    n_requests=60)

snap = client.telemetry
print(f"admitted={snap['admitted']} completed={snap['completed']} "
      f"violations={snap['violations']} dropped={snap['dropped']} "
      f"failovers={snap['failovers']}")
rerouted = sum(h.telemetry["rerouted"] > 0 for h in handles)
print(f"{rerouted} requests were displaced by the SEU and re-served "
      f"by the surviving boards")
assert snap["completed"] + snap["dropped"] == snap["admitted"]
assert rerouted > 0, "the fault should displace in-flight work"
