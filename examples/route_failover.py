"""Minimal fleet-router example: three pools, one SEU, zero lost requests.

    PYTHONPATH=src python examples/route_failover.py

Routes 60 mixed-SLO UrsoNet inferences across two DPU+VPU boards and an
EdgeTPU sidecar.  At t=0.5s board-b takes a transient fault; its queued
and in-flight requests are rescheduled over the survivors and every
admitted request completes (deadline misses are *reported*, not dropped).
"""
import numpy as np

from repro.core.cost_model import layer_costs_from_convspecs
from repro.models.cnn import ursonet_table1_layers
from repro.router import (AcceleratorPool, CostModelExecutor,
                          FailoverController, Router, RouterRequest,
                          SLO_CLASSES)
from repro.runtime.fault import PoolFault, PoolFaultInjector

layers = layer_costs_from_convspecs(ursonet_table1_layers())
pools = [
    AcceleratorPool("board-a", ("mpsoc_dpu", "myriadx_vpu"),
                    CostModelExecutor(layers), capacity=2),
    AcceleratorPool("board-b", ("mpsoc_dpu", "myriadx_vpu"),
                    CostModelExecutor(layers), capacity=2),
    AcceleratorPool("sidecar", ("edge_tpu", "cortex_a53"),
                    CostModelExecutor(layers), capacity=1),
]
router = Router(layers, pools, accuracy_penalty={"mpsoc_dpu": 0.05})
fc = FailoverController(router, PoolFaultInjector(
    [PoolFault("board-b", at_s=0.5, duration_s=1.0)]))

rng = np.random.default_rng(0)
classes = list(SLO_CLASSES.values())
t, arrivals = 0.0, []
for i in range(60):
    t += rng.exponential(1.0 / 30.0)                     # ~30 req/s
    arrivals.append(RouterRequest(i, classes[rng.integers(len(classes))], t))

t, i = 0.0, 0
while i < len(arrivals) or router.outstanding or fc.pending_faults:
    t += 0.002
    fc.poll(t)
    while i < len(arrivals) and arrivals[i].arrival_s <= t:
        router.submit(arrivals[i], t)
        i += 1
    router.step(t)

snap = router.telemetry.snapshot()
print(f"admitted={snap['admitted']} completed={snap['completed']} "
      f"violations={snap['violations']} dropped={snap['dropped']} "
      f"failovers={snap['failovers']}")
assert snap["completed"] + snap["dropped"] == snap["admitted"]
