"""The paper's own workload, end to end: UrsoNet satellite pose estimation
with the MPAI partition (Table I reproduction at example scale).

Trains the four software conditions on the synthetic soyuz-like task and
prints the Table-I-shaped comparison: latency from the calibrated cost
model, accuracy measured.

    PYTHONPATH=src python examples/pose_estimation_mpai.py [--steps 400]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.table1_ursonet import PAPER_ROWS, latency_rows
from repro.models.cnn import UrsoNetConfig
from repro.pose import run_condition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    print("=== latency (cost model, full-size UrsoNet @1280x960) ===")
    print(f"{'processor':18s} {'model ms':>9s} {'paper ms':>9s}")
    for r in latency_rows():
        print(f"{r['processor']:18s} {r['model_ms']:9.0f} {r['paper_ms']:9.0f}")

    print("\n=== accuracy (measured, synthetic pose task) ===")
    cfg = UrsoNetConfig(name="example", image_hw=(96, 128),
                        widths=(16, 32, 64), blocks_per_stage=1, fc_dim=128)
    conditions = ["fp32", "int8_ptq", "int8_qat", "mpai"]
    rows = []
    for cond in conditions:
        r = run_condition(cond, cfg, steps=args.steps, batch=args.batch)
        rows.append(r)
        print(f"{cond:10s} LOCE={r['loce']:.3f} m  ORIE={r['orie']:.2f} deg  "
              f"(train loss {r['final_train_loss']:.3f})")

    by = {r["condition"]: r for r in rows}
    print("\nTable-I delta structure:")
    print(f"  PTQ degrades vs fp32:   dORIE="
          f"{by['int8_ptq']['orie'] - by['fp32']['orie']:+.2f} deg")
    print(f"  MPAI recovers (QAT+bf16 head): dORIE="
          f"{by['mpai']['orie'] - by['fp32']['orie']:+.2f} deg")
    print("  (paper: DPU-alone ORIE 9.29 vs baseline 7.28; DPU+VPU 7.32)")


if __name__ == "__main__":
    main()
