"""Orbit-aware serving: an energy-capped, self-scaling fleet, end to end.

The data plane is PR 3's ``repro.serving`` facade; this example attaches
the ``repro.orbit`` control plane on top and walks one day-in-the-life:

  1. declare the fleet (FleetSpec) and the orbit (OrbitSpec): a
     sunlit/eclipse power cycle, a battery-sized energy bucket, and a
     pool-cloning scaling policy;
  2. submit mixed traffic across an eclipse — watch offline work defer
     while downlink-critical work keeps flowing;
  3. sunlight returns: the backlog releases at the rate the solar array
     funds, the autoscaler grows the board family against the queue and
     retires the clones when it drains;
  4. read one telemetry schema for all of it: budget ratio, mode
     transitions, scale actions, per-pool energy.

    PYTHONPATH=src python examples/orbit_eclipse.py [--requests 200]
"""
import argparse
import json

from repro.launch.route import vision_fleet_spec
from repro.launch.orbit import MIX, eclipse_orbit_spec, mix_demand_w
from repro.orbit import ScalingPolicy, budget_j
from repro.router import SLO_CLASSES
from repro.serving.traffic import open_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # 1. fleet + orbit as data (both JSON-round-trippable specs); the
    # traffic mix is the launcher/bench scenario's canonical one
    client = vision_fleet_spec().build()
    demand_w = mix_demand_w(client, args.rate, mix=MIX)
    ospec = eclipse_orbit_spec(
        demand_w,
        scaling=ScalingPolicy(template="board-a", max_pools=3,
                              queue_high=6, cooldown_s=0.1))
    print("orbit:", json.dumps(ospec.to_dict(), indent=2))
    ctrl = ospec.attach(client)

    # 2-3. mixed open-loop traffic across the eclipse; the client's own
    # clock drives the controller (bucket, modes, release, autoscaler)
    classes = [SLO_CLASSES[n] for n, _ in MIX]
    handles = open_loop(client, classes, [w for _, w in MIX],
                        rate_hz=args.rate, n_requests=args.requests,
                        seed=args.seed)
    for _ in range(300):
        client.step()                     # idle tail: clones retire

    # 4. one schema for the whole story
    snap = client.telemetry
    budget = budget_j(ospec.profile(), ospec.initial_frac * ospec.bucket_j,
                      0.0, client.now)
    print(f"\n{snap['completed']} completed / {snap['dropped']} dropped; "
          f"{snap['energy_deferred']} deferred through the eclipse, "
          f"{snap['violations']} SLO violations (latency traded for "
          f"energy)")
    print(f"energy: {snap['energy_j']:.3f} J of a {budget:.3f} J "
          f"orbit-average budget "
          f"({snap['energy_j'] / budget:.2f}x)")
    print("mode transitions:", ctrl.report()["transitions"])
    print("scale actions:", ctrl.report()["scale_actions"])
    print("per-pool energy:",
          {k: v["energy_j"] for k, v in snap["pools"].items()})
    assert all(h.done for h in handles), "stranded requests"


if __name__ == "__main__":
    main()
