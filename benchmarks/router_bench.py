"""Router benchmark: synthetic open-loop traffic through the fleet router.

    PYTHONPATH=src python -m benchmarks.router_bench [--out results.json]

Measures, per load level (requests/s):
  * dispatch throughput — admitted requests / wall second of router code
    (the routing fabric itself, not the simulated device time);
  * end-to-end p50/p99 latency per SLO class on the virtual clock;
  * SLO violation + rejection rates;
and the failover scenario: same traffic with a mid-run pool loss.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and optionally writes the full metrics dict as JSON.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.cost_model import layer_costs_from_convspecs
from repro.launch.route import open_loop
from repro.models.cnn import ursonet_table1_layers
from repro.router import (AcceleratorPool, CostModelExecutor,
                          FailoverController, Router, SLO_CLASSES)
from repro.runtime.fault import PoolFault, PoolFaultInjector

MIX = [("downlink-critical", 0.2), ("realtime-tracking", 0.3),
       ("background-science", 0.3), ("bulk-reprocess", 0.2)]


def build(layers, fault_at=None):
    pools = [
        AcceleratorPool("board-a", ("mpsoc_dpu", "myriadx_vpu"),
                        CostModelExecutor(layers), capacity=2, max_window=4),
        AcceleratorPool("board-b", ("mpsoc_dpu", "myriadx_vpu"),
                        CostModelExecutor(layers), capacity=2, max_window=4),
        AcceleratorPool("sidecar", ("edge_tpu", "cortex_a53"),
                        CostModelExecutor(layers), capacity=1, max_window=2),
    ]
    router = Router(layers, pools, accuracy_penalty={"mpsoc_dpu": 0.05})
    faults = ([PoolFault("board-b", at_s=fault_at, duration_s=3.0)]
              if fault_at is not None else [])
    return router, FailoverController(router, PoolFaultInjector(faults))


def run_scenario(name: str, rate_hz: float, n_requests: int,
                 fault_at=None, seed: int = 0) -> dict:
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    router, fc = build(layers, fault_at=fault_at)
    classes = [SLO_CLASSES[n] for n, _ in MIX]
    weights = [w for _, w in MIX]

    wall0 = time.perf_counter()
    open_loop(router, fc, classes, weights, rate_hz=rate_hz,
              n_requests=n_requests, seed=seed)
    wall = time.perf_counter() - wall0

    snap = router.telemetry.snapshot()
    admitted = max(snap["admitted"], 1)
    return {
        "scenario": name,
        "rate_hz": rate_hz,
        "requests": n_requests,
        "fault_at": fault_at,
        "wall_s": round(wall, 4),
        "dispatch_throughput_rps": round(snap["admitted"] / wall, 1),
        "us_per_request": round(wall * 1e6 / admitted, 1),
        "admitted": snap["admitted"],
        "rejected": snap["rejected"],
        "completed": snap["completed"],
        "dropped": snap["dropped"],
        "violations": snap["violations"],
        "violation_rate": round(snap["violations"] / admitted, 4),
        "failovers": snap["failovers"],
        "latency_by_class": snap["latency_by_class"],
        "violations_by_class": snap["violations_by_class"],
    }


def main(csv: bool = True, out: str | None = None, n: int = 400):
    scenarios = [
        ("router_steady_20rps", 20.0, None),
        ("router_steady_60rps", 60.0, None),
        ("router_overload_200rps", 200.0, None),
        ("router_failover_60rps", 60.0, 2.0),
    ]
    results = [run_scenario(name, rate, n, fault_at=fa)
               for name, rate, fa in scenarios]
    if csv:
        for r in results:
            crit = r["latency_by_class"].get("downlink-critical", {})
            print(f"{r['scenario']},{r['us_per_request']},"
                  f"rps={r['dispatch_throughput_rps']};"
                  f"p50={crit.get('p50', 0)};p99={crit.get('p99', 0)};"
                  f"viol={r['violation_rate']};rej={r['rejected']};"
                  f"failovers={r['failovers']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--requests", type=int, default=400)
    args = ap.parse_args()
    main(out=args.out, n=args.requests)
