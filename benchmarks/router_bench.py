"""Router benchmark: synthetic open-loop traffic through the serving
facade (the same ``FleetSpec`` -> ``ServingClient`` path production
call sites use — the benchmark measures the facade it drives).

    PYTHONPATH=src python -m benchmarks.router_bench [--out BENCH_router.json]

Measures, per load level (requests/s):
  * dispatch throughput — admitted requests / wall second of router code
    (the routing fabric itself, not the simulated device time);
  * end-to-end p50/p99 latency per SLO class on the virtual clock;
  * SLO violation + rejection rates;
the failover scenario (same traffic with a mid-run pool loss); and the
*engine-backed routed serving* scenario: real decode on a tiny config
through an engine pool vs the windowed baseline pool, reporting
tokens/s for both and the speedup (``--min-lm-speedup`` turns the ratio
into a CI gate — the facade path must not fall behind the PR 2
windowed baseline).

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and optionally writes the full metrics dict as JSON.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.launch.route import vision_fleet_spec
from repro.router import SLO_CLASSES, SLOClass
from repro.serving import FaultSpec, FleetSpec, LMWork, PoolSpec
from repro.serving.traffic import open_loop

MIX = [("downlink-critical", 0.2), ("realtime-tracking", 0.3),
       ("background-science", 0.3), ("bulk-reprocess", 0.2)]


def run_scenario(name: str, rate_hz: float, n_requests: int,
                 fault_at=None, seed: int = 0) -> dict:
    # the demo's canonical fleet, with this scenario's fault schedule
    faults = ([FaultSpec("board-b", at_s=fault_at, duration_s=3.0)]
              if fault_at is not None else [])
    client = vision_fleet_spec(faults=faults).build()
    classes = [SLO_CLASSES[n] for n, _ in MIX]
    weights = [w for _, w in MIX]

    wall0 = time.perf_counter()
    open_loop(client, classes, weights, rate_hz=rate_hz,
              n_requests=n_requests, seed=seed)
    wall = time.perf_counter() - wall0

    snap = client.telemetry
    admitted = max(snap["admitted"], 1)
    return {
        "scenario": name,
        "rate_hz": rate_hz,
        "requests": n_requests,
        "fault_at": fault_at,
        "wall_s": round(wall, 4),
        "dispatch_throughput_rps": round(snap["admitted"] / wall, 1),
        "us_per_request": round(wall * 1e6 / admitted, 1),
        "admitted": snap["admitted"],
        "rejected": snap["rejected"],
        "completed": snap["completed"],
        "dropped": snap["dropped"],
        "violations": snap["violations"],
        "violation_rate": round(snap["violations"] / admitted, 4),
        "failovers": snap["failovers"],
        "latency_by_class": snap["latency_by_class"],
        "violations_by_class": snap["violations_by_class"],
    }


# ---------------------------------------------------------------------------
# engine-backed routed serving vs the windowed baseline (real decode)
# ---------------------------------------------------------------------------
def _tiny_lm():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=256, remat=False)


def run_lm_scenario(n_requests: int = 32, max_new_hi: int = 24,
                    seed: int = 0, repeats: int = 3) -> dict:
    """The same mixed-``max_new`` routed workload through an engine pool
    and a windowed-baseline pool; tokens/s from the pool telemetry.

    Arrivals come in bursts (the full-queue regime the engine exists
    for — trickled one-request batches measure prefill amortization,
    which ``decode_bench`` already covers), and ``max_window >
    max_slots`` hands the engine pool batches wider than its slot
    count, so completed slots backfill mid-batch — the continuous-
    batching advantage under routing.  The headline ratio uses
    process-CPU time aggregated over every repeat (co-tenant wall noise
    on shared CI boxes swings per-run wall tokens/s by ±40%; same
    policy as ``decode_bench``); the wall-clock pool telemetry is
    reported alongside.
    """
    import time

    import jax

    from repro.models import transformer as T

    cfg = _tiny_lm()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    relaxed = SLOClass("lm-offline", max_latency_s=600.0)
    prompt_len = 8

    def payload(rng):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(2, prompt_len))
                              ).astype("int32")
        return LMWork(prompt, max_new=int(rng.integers(1, max_new_hi + 1)))

    out = {"scenario": "router_lm_serving", "requests": n_requests,
           "repeats": repeats, "max_new_mix": [1, max_new_hi]}
    for backend in ("windowed", "engine"):
        spec = FleetSpec(
            pools=[PoolSpec("lm", ("tpu_v5e_bf16",), backend=backend,
                            capacity=1, max_window=2 * 8, max_wait_s=0.0,
                            max_slots=8, prompt_len=prompt_len,
                            max_new=max_new_hi)],
            workload="transformer", seq_len=prompt_len)
        client = spec.build(model=(cfg, params))   # one build: warm once
        tokens = cpu = 0.0
        for rep in range(repeats):
            c0 = time.process_time()
            handles = open_loop(client, [relaxed], [1.0], rate_hz=2000.0,
                                n_requests=n_requests, seed=seed + rep,
                                dt=0.05, payload_fn=payload)
            cpu += time.process_time() - c0
            tokens += sum(len(h.tokens) for h in handles)
        pool = client.telemetry["pools"]["lm"]
        out[backend] = {
            "tokens_generated": pool["tokens_generated"],
            "cpu_s": round(cpu, 4),
            "tokens_per_cpu_s": round(tokens / cpu, 2),
            "busy_s": pool["busy_s"],
            "tokens_per_s": pool["tokens_per_s"],
            "decode_tokens_per_s": pool["decode_tokens_per_s"],
            "mean_occupancy": pool["slot_occupancy"]["mean"],
            "deferrals": pool["deferrals"],
        }
    out["speedup_tokens_per_s"] = round(
        out["engine"]["tokens_per_cpu_s"]
        / max(out["windowed"]["tokens_per_cpu_s"], 1e-9), 3)
    return out


def main(csv: bool = True, out: str | None = None, n: int = 400,
         smoke: bool = False, min_lm_speedup: float = 0.0):
    scenarios = [
        ("router_steady_20rps", 20.0, None),
        ("router_steady_60rps", 60.0, None),
        ("router_overload_200rps", 200.0, None),
        ("router_failover_60rps", 60.0, 2.0),
    ]
    results = [run_scenario(name, rate, n, fault_at=fa)
               for name, rate, fa in scenarios]
    lm = run_lm_scenario(n_requests=48 if smoke else 64,
                         repeats=2 if smoke else 3)
    results.append(lm)
    if csv:
        for r in results[:-1]:
            crit = r["latency_by_class"].get("downlink-critical", {})
            print(f"{r['scenario']},{r['us_per_request']},"
                  f"rps={r['dispatch_throughput_rps']};"
                  f"p50={crit.get('p50', 0)};p99={crit.get('p99', 0)};"
                  f"viol={r['violation_rate']};rej={r['rejected']};"
                  f"failovers={r['failovers']}")
        us = 1e6 / max(lm["engine"]["tokens_per_cpu_s"], 1e-9)
        print(f"{lm['scenario']},{us:.1f},"
              f"eng_tps={lm['engine']['tokens_per_cpu_s']};"
              f"win_tps={lm['windowed']['tokens_per_cpu_s']};"
              f"speedup={lm['speedup_tokens_per_s']};"
              f"eng_decode_tps={lm['engine']['decode_tokens_per_s']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    if min_lm_speedup and lm["speedup_tokens_per_s"] < min_lm_speedup:
        raise SystemExit(
            f"routed serving perf regression: engine/windowed tokens/s "
            f"{lm['speedup_tokens_per_s']} < {min_lm_speedup}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--min-lm-speedup", type=float, default=0.0,
                    help="fail unless the engine-backed facade beats the "
                         "windowed baseline by this tokens/s factor")
    args = ap.parse_args()
    main(out=args.out, n=100 if args.smoke else args.requests,
         smoke=args.smoke, min_lm_speedup=args.min_lm_speedup)
