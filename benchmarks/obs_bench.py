"""Flight-recorder overhead benchmark: tracing on vs off.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--check] \
        [--out BENCH_obs.json] [--trace-out trace.json] [--spans-out s.jsonl]

One scenario, ``obs_overhead``: the same engine-backed fleet serves the
same seeded workload three times — flight recorder off, recorder on,
and SLO metrics plane on (SLI registry + burn-rate engine attached,
recorder off) — interleaved best-of-N on a process-CPU basis (the same
noise policy as ``decode_bench`` / ``coproc_bench``).  The traced arm
records the full span chain of every request (root request span, queue,
serve, engine admit/decode-step lane spans) plus the per-tick fleet
time-series; each arm's overhead is ``1 - arm_tokens_per_s /
off_tokens_per_s``.

Under ``--check`` the run fails when:

  * tracing or SLO-engine overhead exceeds ``--max-overhead`` (default
    3% — both planes must be cheap enough to leave on in flight);
  * the SLO arm fired any alert, or its SLI completion count disagrees
    with the admission count (a clean run must score clean);
  * the untraced arm recorded any span at all (tracing-off must be
    zero-record, not just cheap);
  * any traced request's span chain is left open or unterminated (the
    no-orphan invariant), or the outcome tally disagrees with the
    admission count;
  * the exported Chrome trace contains a malformed event.

With ``--trace-out`` / ``--spans-out`` the traced arm's Chrome
``trace_event`` JSON and span JSONL are written as artifacts (CI uploads
them next to ``BENCH_obs.json``); the Chrome file opens directly in
Perfetto / ``chrome://tracing``.  ``--slo-report`` writes the SLO arm's
``SLO_report.json`` judgment (spec, objectives, burns, budgets, SLIs).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPT_LEN = 8
MAX_NEW = 8
BLOCK = 8


def _tiny_lm():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=256, remat=False)


def _model():
    import jax

    from repro.models import transformer as T
    cfg = _tiny_lm()
    return cfg, T.model_init(jax.random.PRNGKey(0), cfg)


def _fleet(slots: int):
    from repro.serving import FleetSpec, PoolSpec
    return FleetSpec(
        pools=[PoolSpec("lm", ("tpu_v5e_bf16",), backend="engine",
                        capacity=1, max_window=slots, max_wait_s=0.0,
                        max_slots=slots, prompt_len=PROMPT_LEN,
                        max_new=MAX_NEW, block_size=BLOCK)],
        workload="transformer", seq_len=PROMPT_LEN)


def _serve_once(client, n_requests: int, seed: int):
    """One timed pass: submit ``n_requests`` seeded prompts, drain, and
    return (tokens_per_cpu_s, tokens)."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN + 1)))
               .astype(np.int32) for _ in range(n_requests)]
    c0 = time.process_time()
    handles = [client.submit(p, slo="offline", max_new=MAX_NEW)
               for p in prompts]
    client.drain()
    cpu = time.process_time() - c0
    toks = sum(len(h.tokens) for h in handles)
    return toks / max(cpu, 1e-9), toks


def _validate_chrome(trace: dict) -> int:
    """Minimal trace_event validity: every event carries the keys its
    phase requires; returns the event count."""
    evs = trace["traceEvents"]
    for ev in evs:
        assert {"ph", "pid", "tid", "name"} <= set(ev), ev
        if ev["ph"] != "M":
            assert "ts" in ev, ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0, ev
    return len(evs)


def _bench_slo_spec():
    """Loose objectives for the clean bench workload: wide latency
    bounds and a fat budget, so the arm measures the metrics plane's
    cost, never an alert storm."""
    from repro.obs import SLOObjective, SLOSpec
    return SLOSpec(objectives=[
        SLOObjective("offline", p99_ttft_s=60.0, p99_e2e_s=120.0,
                     availability=0.5)])


def run_overhead(n_requests: int = 24, repeats: int = 5, slots: int = 4,
                 seed: int = 0, check: bool = False,
                 max_overhead: float = 0.03, trace_out: str | None = None,
                 spans_out: str | None = None,
                 slo_report_out: str | None = None) -> dict:
    cfg, params = _model()
    clients = {}
    for kind in ("off", "on", "slo"):
        spec = _fleet(slots)
        if kind == "slo":
            spec.slo = _bench_slo_spec()
        clients[kind] = spec.build(model=(cfg, params))
        if kind == "on":
            clients[kind].enable_tracing()
    best = {"off": 0.0, "on": 0.0, "slo": 0.0}
    # interleave the repeats so co-tenant drift on a shared box hits
    # both arms alike (best-of-N per arm, process-CPU basis)
    for rep in range(repeats):
        for kind, client in clients.items():
            tps, _ = _serve_once(client, n_requests, seed + rep)
            best[kind] = max(best[kind], tps)
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)
    slo_overhead = 1.0 - best["slo"] / max(best["off"], 1e-9)

    on = clients["on"]
    slo = clients["slo"]
    tr = on.tracer
    out = {
        "scenario": "obs_overhead",
        "requests_per_rep": n_requests, "repeats": repeats,
        "slots": slots, "max_new": MAX_NEW,
        "off_tokens_per_cpu_s": round(best["off"], 1),
        "on_tokens_per_cpu_s": round(best["on"], 1),
        "slo_tokens_per_cpu_s": round(best["slo"], 1),
        "overhead": round(overhead, 4),
        "slo_overhead": round(slo_overhead, 4),
        "max_overhead": max_overhead,
        "tracer": tr.summary(),
        "timeseries": on.timeseries.summary(),
        "slo_alerts": slo.telemetry["alerts"],
        "slo_completed": slo.telemetry["slis"]["fleet"]["completed"],
    }
    if slo_report_out:
        from repro.obs import export_slo_report
        export_slo_report(slo, slo_report_out)
        out["slo_report_path"] = str(slo_report_out)
    if trace_out:
        from repro.obs import export_chrome_trace
        trace = export_chrome_trace(on, trace_out)
        out["trace_events"] = _validate_chrome(trace)
        out["trace_path"] = str(trace_out)
    if spans_out:
        from repro.obs import export_spans_jsonl
        out["spans_written"] = export_spans_jsonl(on, spans_out)
        out["spans_path"] = str(spans_out)

    if check:
        assert len(clients["off"].tracer.spans) == 0, \
            "tracing-off arm recorded spans — the off path is not off"
        assert not tr.open_spans(), \
            f"orphan spans after drain: {tr.open_spans()}"
        n_chains = repeats * n_requests
        assert len(tr.request_ids) == n_chains, \
            (len(tr.request_ids), n_chains)
        assert all(tr.closed(rid) for rid in tr.request_ids), \
            "a traced request never saw a terminal outcome"
        assert tr.summary()["outcomes"].get("completed", 0) == n_chains
        assert overhead <= max_overhead, (
            f"flight-recorder overhead {overhead:.1%} exceeds the "
            f"{max_overhead:.0%} gate "
            f"(off {best['off']:.0f} vs on {best['on']:.0f} tok/cpu-s)")
        assert slo_overhead <= max_overhead, (
            f"SLO metrics-plane overhead {slo_overhead:.1%} exceeds the "
            f"{max_overhead:.0%} gate "
            f"(off {best['off']:.0f} vs slo {best['slo']:.0f} tok/cpu-s)")
        n_chains = repeats * n_requests
        assert out["slo_completed"] == n_chains, \
            (out["slo_completed"], n_chains)
        tally = out["slo_alerts"]
        assert tally["pages_fired"] == 0 and tally["warns_fired"] == 0, (
            f"the clean bench workload fired alerts: {tally}")
    return out


def main(csv: bool = True, out: str | None = None, smoke: bool = False,
         check: bool = False, max_overhead: float = 0.03,
         trace_out: str | None = None, spans_out: str | None = None,
         slo_report_out: str | None = None):
    results = [
        # keep 5 repeats even in smoke: the overhead gate is a
        # best-of-N CPU-time ratio and needs the samples against noise
        run_overhead(n_requests=16 if smoke else 32, repeats=5,
                     check=check, max_overhead=max_overhead,
                     trace_out=trace_out, spans_out=spans_out,
                     slo_report_out=slo_report_out),
    ]
    if csv:
        r = results[0]
        us = 1e6 / max(r["on_tokens_per_cpu_s"], 1e-9)
        print(f"{r['scenario']},{us:.1f},"
              f"off_tps={r['off_tokens_per_cpu_s']};"
              f"on_tps={r['on_tokens_per_cpu_s']};"
              f"slo_tps={r['slo_tokens_per_cpu_s']};"
              f"overhead={r['overhead']};"
              f"slo_overhead={r['slo_overhead']};"
              f"spans={r['tracer']['spans']};"
              f"open={r['tracer']['open']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--check", action="store_true",
                    help="fail on overhead > --max-overhead, orphan "
                         "spans, or a malformed Chrome trace")
    ap.add_argument("--max-overhead", type=float, default=0.03,
                    help="with --check: max tracing-on tokens/s loss "
                         "vs tracing-off (fraction; default 0.03)")
    ap.add_argument("--trace-out", default=None,
                    help="write the traced arm's Chrome trace JSON here")
    ap.add_argument("--spans-out", default=None,
                    help="write the traced arm's span JSONL here")
    ap.add_argument("--slo-report", default=None,
                    help="write the SLO arm's SLO_report.json here")
    args = ap.parse_args()
    main(out=args.out, smoke=args.smoke, check=args.check,
         max_overhead=args.max_overhead, trace_out=args.trace_out,
         spans_out=args.spans_out, slo_report_out=args.slo_report)
