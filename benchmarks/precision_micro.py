"""Microbenchmark: the precision-dispatched matmul (pdot) under each MPAI
policy, plus quantization overhead.  Wall-times are CPU (this container);
they validate relative behaviour of the XLA paths — TPU rates are the
cost model's job.  Also reports the analytic v5e-int8 speedup the MPAI
backbone segment gets over bf16 (the DPU-vs-VPU ratio at pod scale)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionPolicy
from repro.core.quantization import pdot, quantize

M, K, N = 512, 2048, 2048


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(csv: bool = True):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    wq = quantize(w, channel_axis=-1)

    fns = {
        "pdot_bf16": jax.jit(lambda a, b: pdot(a, b, PrecisionPolicy.bf16())),
        "pdot_fp32": jax.jit(lambda a, b: pdot(a, b, PrecisionPolicy.fp32())),
        "pdot_int8_xla": jax.jit(
            lambda a, b: pdot(a, b, PrecisionPolicy.int8())),
        "fake_quant_qat": jax.jit(
            lambda a, b: pdot(a, b, PrecisionPolicy.int8_qat())),
    }
    rows = []
    for name, fn in fns.items():
        us = _time(fn, x, w)
        rows.append((name, us))
        if csv:
            gf = 2 * M * K * N / (us * 1e-6) / 1e9
            print(f"micro_{name},{us:.0f},cpu_gflops={gf:.1f}")
    qt = jax.jit(lambda a: quantize(a))
    us = _time(lambda a: qt(a).values, x)
    if csv:
        print(f"micro_quantize_activation,{us:.0f},"
              f"gbps={M * K * 4 / (us * 1e-6) / 1e9:.1f}")
        # the roofline story: v5e int8 MXU = 2x bf16 peak; MPAI's backbone
        # therefore upper-bounds at 2x for compute-bound segments
        print("micro_v5e_policy_ratio,0,int8_peak/bf16_peak=2.0;"
              "weights_bytes_ratio=0.5")
    return rows


if __name__ == "__main__":
    main()
