"""Orbit-controller benchmark: eclipse transition + live LM autoscaling.

    PYTHONPATH=src python -m benchmarks.orbit_bench [--smoke] [--check] \
        [--out BENCH_orbit.json]

Two scenarios through the ``repro.serving`` facade + ``repro.orbit``
control plane:

* ``orbit_eclipse_{on,off}`` — the launcher's eclipse-transition
  scenario (``repro.launch.orbit``, cost-model vision fleet, fully
  deterministic virtual clock) with the controller attached vs the
  uncapped baseline.  The capped fleet must keep cumulative ``energy_j``
  within the orbit-average budget (ratio <= 1.05); the baseline is
  expected to overshoot it (ratio > 1.05) — that gap is the whole point
  of the controller.
* ``orbit_lm_autoscale`` — burst traffic into a single tiny engine-
  backed LM pool with a pool-cloning :class:`ScalingPolicy`: queue
  depth grows the family live (``ServingClient.add_pool``), the
  post-burst idle retires the clones gracefully (``retire_pool``), and
  every request's token stream must arrive complete — an autoscaler
  retirement never drops in-flight work.  Reports tokens/s with the
  autoscaler on vs. off.

``--check`` turns the invariants above into hard gates (CI smoke);
``--out`` writes the full reports next to ``BENCH_decode.json`` /
``BENCH_router.json``.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.launch.orbit import run_eclipse_scenario
from repro.orbit import OrbitSpec, PhaseSpec, ScalingPolicy
from repro.serving import FleetSpec, LMWork, PoolSpec, SLOClass
from repro.serving.traffic import open_loop


def _tiny_lm():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=256, remat=False)


def run_lm_autoscale(n_requests: int = 32, max_new_hi: int = 12,
                     seed: int = 0) -> dict:
    """Burst-routed decode with and without the autoscaler.

    One engine pool ("lm", 2 slots) takes the whole burst; with the
    controller attached, queue depth spawns up to two clones that share
    the backlog, and the idle tail retires them.  This is a
    *correctness* scenario — its gate is that every stream survives the
    add/retire churn intact — not a perf one: all pools decode on one
    host CPU, so cloning splits wall time rather than adding it, and the
    reported latency/throughput deltas mostly measure occupancy dilution
    (on real multi-device fleets the same policy adds actual capacity).
    """
    import jax

    from repro.models import transformer as T

    cfg = _tiny_lm()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    relaxed = SLOClass("lm-offline", max_latency_s=600.0)
    prompt_len = 8

    def payload(rng):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(2, prompt_len))
                              ).astype("int32")
        return LMWork(prompt, max_new=int(rng.integers(1, max_new_hi + 1)))

    out = {"scenario": "orbit_lm_autoscale", "requests": n_requests,
           "max_new_mix": [1, max_new_hi]}
    for scaled in (False, True):
        spec = FleetSpec(
            pools=[PoolSpec("lm", ("tpu_v5e_bf16",), backend="engine",
                            capacity=1, max_window=4, max_wait_s=0.0,
                            max_slots=2, prompt_len=prompt_len,
                            max_new=max_new_hi)],
            workload="transformer", seq_len=prompt_len)
        client = spec.build(model=(cfg, params))
        if scaled:
            # power is a non-issue here (huge sunlit bucket): this
            # scenario isolates the autoscaler
            OrbitSpec(
                phases=[PhaseSpec("sunlit", 60.0, 1e9)], bucket_j=1e9,
                scaling=ScalingPolicy(template="lm", min_pools=1,
                                      max_pools=3, queue_high=4,
                                      queue_low=0, cooldown_s=0.2),
            ).attach(client)
        c0 = time.process_time()
        handles = open_loop(client, [relaxed], [1.0], rate_hz=2000.0,
                            n_requests=n_requests, seed=seed, dt=0.05,
                            payload_fn=payload)
        cpu = time.process_time() - c0
        for _ in range(60):                 # idle tail: clones retire
            client.step(0.05)
        snap = client.telemetry
        tokens = sum(len(h.tokens) for h in handles)
        complete = all(
            h.done and not h.result().dropped
            and len(h.tokens) == h._work.max_new for h in handles)
        # decode-only tokens/s over the whole family (clone jit compiles
        # land in cpu_s, never in decode_s); the scale-up win itself is
        # queue latency on the fleet clock
        dec_tok = sum(p["decode_tokens"] for p in snap["pools"].values())
        dec_s = sum(p["decode_s"] for p in snap["pools"].values())
        lat = snap["latency_by_class"]["lm-offline"]
        key = "scaled" if scaled else "fixed"
        out[key] = {
            "tokens": tokens,
            "cpu_s": round(cpu, 4),
            "decode_tokens_per_s": round(dec_tok / max(dec_s, 1e-9), 2),
            "latency_p50_s": lat["p50"],
            "latency_p99_s": lat["p99"],
            "pools_added": snap["pools_added"],
            "pools_retired": snap["pools_retired"],
            "dropped": snap["dropped"],
            "live_pools": sorted(client.router.pools),
            "streams_complete": complete,
        }
    out["latency_p50_speedup"] = round(
        out["fixed"]["latency_p50_s"]
        / max(out["scaled"]["latency_p50_s"], 1e-9), 3)
    return out


def main(csv: bool = True, out: str | None = None, smoke: bool = False,
         check: bool = False):
    # the eclipse scenario keeps its full size even in smoke: it is
    # cost-model-only (cheap), and a shorter trace no longer out-demands
    # the battery's initial charge, so the baseline would not overshoot
    n = 300
    on = run_eclipse_scenario(n_requests=n, controlled=True)
    off = run_eclipse_scenario(n_requests=n, controlled=False)
    lm = run_lm_autoscale(n_requests=24 if smoke else 48)
    results = [on, off, lm]
    if csv:
        for r in (on, off):
            us = r["t_end_s"] * 1e6 / max(r["admitted"], 1)
            print(f"{r['scenario']},{us:.1f},"
                  f"energy_ratio={r['energy_ratio']};"
                  f"deferred={r['deferred']};"
                  f"viol={r['violation_rate']};"
                  f"dropped={r['dropped']};t_end={r['t_end_s']}")
        us = 1e6 / max(lm["scaled"]["decode_tokens_per_s"], 1e-9)
        print(f"{lm['scenario']},{us:.1f},"
              f"scaled_decode_tps={lm['scaled']['decode_tokens_per_s']};"
              f"fixed_decode_tps={lm['fixed']['decode_tokens_per_s']};"
              f"p50_speedup={lm['latency_p50_speedup']};"
              f"added={lm['scaled']['pools_added']};"
              f"retired={lm['scaled']['pools_retired']};"
              f"streams_complete={lm['scaled']['streams_complete']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    if check:
        problems = []
        if on["energy_ratio"] > 1.05:
            problems.append(
                f"capped fleet overshot the orbit budget: "
                f"{on['energy_ratio']} > 1.05")
        if off["energy_ratio"] <= 1.05:
            problems.append(
                f"uncapped baseline stayed inside the budget "
                f"({off['energy_ratio']}) — the scenario no longer "
                f"stresses the cap")
        if on["dropped"] or on["unresolved_handles"]:
            problems.append("capped eclipse run dropped/stranded requests")
        for key in ("fixed", "scaled"):
            if not lm[key]["streams_complete"] or lm[key]["dropped"]:
                problems.append(f"lm {key}: incomplete or dropped streams")
        if not (lm["scaled"]["pools_added"] >= 1
                and lm["scaled"]["pools_retired"]
                == lm["scaled"]["pools_added"]):
            problems.append(
                f"autoscaler did not grow and fully retire: "
                f"added={lm['scaled']['pools_added']} "
                f"retired={lm['scaled']['pools_retired']}")
        if problems:
            raise SystemExit("orbit bench check failed: "
                             + "; ".join(problems))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--check", action="store_true",
                    help="gate the energy-cap and stream-safety "
                         "invariants (CI)")
    args = ap.parse_args()
    main(out=args.out, smoke=args.smoke, check=args.check)
