"""Table I reproduction: UrsoNet satellite pose estimation across
processor/precision configurations.

Two halves, matching the paper's columns:
  * LATENCY — cost-model inference latency of full-size UrsoNet
    (1280x960x3 input resampled to the 192x256 backbone) per device row,
    including the MPAI DPU+VPU split (conv backbone on DPU INT8, FC heads
    on VPU FP16, handoff over the board link).
  * ACCURACY — *measured* LOCE/ORIE of a reduced UrsoNet trained on the
    synthetic pose task under the four software conditions (fp32 /
    int8-PTQ / int8-QAT / MPAI partition-aware).  Absolute values differ
    from the paper's soyuz_easy numbers (synthetic data); the reproduction
    target is the DELTA structure: PTQ hurts, MPAI ~= fp32 baseline.
"""
from __future__ import annotations

import time

from repro.core.accelerators import PROFILES
from repro.core.cost_model import layer_costs_from_convspecs, segment_cost
from repro.core.scheduler import mpai_reference_plan
from repro.models.cnn import UrsoNetConfig, ursonet_table1_layers
from repro.pose import run_condition

PAPER_ROWS = {  # processor: (inference_ms, LOCE m, ORIE deg) from Table I
    "cortex_a53": (9890, 0.68, 7.28),
    "cortex_a53_fp16": (4210, 0.87, 8.09),
    "myriadx_vpu": (246, 0.69, 8.71),
    "edge_tpu": (149, 0.66, 7.60),
    "mpsoc_dpu": (53, 0.96, 9.29),
    "dpu+vpu": (79, 0.68, 7.32),
}


def latency_rows():
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    rows = []
    from benchmarks.fig2_throughput import _edge_tpu_effective
    for dev in ("cortex_a53", "cortex_a53_fp16", "myriadx_vpu", "edge_tpu",
                "mpsoc_dpu"):
        prof = (_edge_tpu_effective(layers) if dev == "edge_tpu"
                else PROFILES[dev])
        c = segment_cost(layers, prof)
        rows.append({"processor": dev, "model_ms": c.latency_s * 1e3,
                     "paper_ms": PAPER_ROWS[dev][0]})
    mp = mpai_reference_plan(layers)
    rows.append({"processor": "dpu+vpu", "model_ms": mp.latency_s * 1e3,
                 "paper_ms": PAPER_ROWS["dpu+vpu"][0]})
    return rows


def accuracy_rows(steps: int = 500, batch: int = 32):
    cfg = UrsoNetConfig(name="bench", image_hw=(96, 128),
                        widths=(16, 32, 64), blocks_per_stage=1, fc_dim=128)
    return [run_condition(c, cfg, steps=steps, batch=batch)
            for c in ("fp32", "int8_ptq", "int8_qat", "mpai")]


def main(steps: int = 500, csv: bool = True):
    t0 = time.perf_counter()
    lrows = latency_rows()
    lat_us = (time.perf_counter() - t0) * 1e6 / len(lrows)
    if csv:
        for r in lrows:
            print(f"table1_latency_{r['processor']},{lat_us:.1f},"
                  f"model_ms={r['model_ms']:.0f};paper_ms={r['paper_ms']}")
    t0 = time.perf_counter()
    arows = accuracy_rows(steps=steps)
    acc_us = (time.perf_counter() - t0) * 1e6 / len(arows)
    if csv:
        for r in arows:
            print(f"table1_accuracy_{r['condition']},{acc_us:.0f},"
                  f"loce={r['loce']:.3f};orie={r['orie']:.2f}")
        by = {r["condition"]: r for r in arows}
        ok_ptq = by["int8_ptq"]["orie"] > by["fp32"]["orie"]
        ok_mpai = (by["mpai"]["orie"] - by["fp32"]["orie"]
                   < by["int8_ptq"]["orie"] - by["fp32"]["orie"])
        print(f"table1_delta_structure,{acc_us:.0f},"
              f"ptq_hurts={ok_ptq};mpai_recovers={ok_mpai}")
    return lrows, arows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    main(steps=ap.parse_args().steps)
