"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows:
  * fig2_*       — Fig. 2 accelerator-throughput reproduction (cost model)
  * table1_*     — Table I UrsoNet latency (cost model) + measured
                   accuracy deltas (fp32 / PTQ / QAT / MPAI)
  * partition_*  — partition-point Pareto sweep (the paper's §IV
                   methodology, implemented)
  * micro_*      — precision-path microbenchmarks
  * roofline_*   — per-(arch x shape) roofline terms from dry-run artifacts
  * router_*     — fleet-router dispatch throughput / SLO violations /
                   failover (synthetic open-loop traffic through the
                   repro.serving facade), plus router_lm_serving:
                   engine-backed routed decode vs the windowed baseline
  * decode_*     — continuous-batching engine vs windowed baseline
                   (tokens/s, inter-token p50/p99, slot occupancy)
  * orbit_*      — orbit-aware fleet controller: eclipse-transition
                   energy cap (capped vs uncapped budget ratio) and live
                   LM pool autoscaling with graceful retirement
  * coproc_*     — co-processing prefill: chunked paged prefill vs the
                   windowed baseline (output equality + prefix-sharing
                   savings) and the disaggregated prefill->decode
                   two-pool fleet vs the unified engine pool
  * obs_*        — flight-recorder overhead: decode tokens/s with
                   per-request span tracing on vs off (``--trace PATH``
                   additionally writes the traced run as Chrome
                   trace_event JSON for Perfetto / chrome://tracing)
  * chaos_*      — radiation-hardened data plane: hardening (per-block
                   digests + fused decode-path verify + scrub) decode
                   overhead vs hardening-off, and a seeded SEU campaign
                   (kv_bitflip / slot_stall / handoff_loss / pool fault)
                   gated on zero corrupted tokens and exactly-once
                   accounting

``--check`` turns invariants into failures across the serving benches:
fleetlint static findings (wall clocks in virtual-clock code, host
syncs in jitted functions, allocator bypasses — see
``src/repro/analysis/``) abort before any bench runs, and
truncated open-loop traces (the ``max_s`` safety net fired, so the
trace silently shrank), chunked-prefill output mismatches, token loss
at the co-processing handoff, mis-attributed per-stage energy, orphan
trace spans, and flight-recorder overhead above 3% all abort the run
instead of printing a smaller number.
"""
from __future__ import annotations

import argparse
import warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer QAT training for Table I accuracy rows")
    ap.add_argument("--skip-accuracy", action="store_true",
                    help="cost-model rows only (fast CI mode)")
    ap.add_argument("--check", action="store_true",
                    help="fail on truncated traces / completeness / "
                         "equality violations in the serving benches")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the obs bench's traced serving run as "
                         "Chrome trace_event JSON (pool lanes, engine "
                         "stages, counter tracks — open in Perfetto / "
                         "chrome://tracing); see the Observability "
                         "quickstart in ROADMAP.md")
    args, _ = ap.parse_known_args()

    from benchmarks import (chaos_bench, coproc_bench, decode_bench,
                            fig2_throughput, obs_bench, orbit_bench,
                            partition_sweep, precision_micro,
                            roofline_bench, router_bench, table1_ursonet)

    if args.check:
        # fleetlint first: a benchmark number measured on a tree with a
        # wall-clock read in virtual-clock code or a host sync in the
        # fused dispatch is already fiction, so static findings abort
        # before any bench spends minutes producing it
        from repro.analysis import run_lint
        report = run_lint()
        if not report.clean:
            for f in report.findings:
                print(f"fleetlint: {f.path}:{f.line}: {f.code} "
                      f"{f.message}")
            for key in report.stale_suppressions:
                print(f"fleetlint: stale suppression {key!r}")
            for path, err in report.parse_errors:
                print(f"fleetlint: {path}: parse error: {err}")
            raise SystemExit(
                f"--check: {len(report.findings)} fleetlint finding(s); "
                f"fix or reason-suppress in "
                f"src/repro/analysis/baseline.json before benchmarking")
        # any open_loop truncation inside a bench is a hard failure:
        # a trace cut by the max_s safety net undercounts the offered
        # load, so every ratio gated downstream would be fiction
        warnings.filterwarnings(
            "error", message=".*open_loop truncated.*",
            category=RuntimeWarning)

    fig2_throughput.main()
    partition_sweep.main()
    precision_micro.main()
    if args.skip_accuracy:
        for r in table1_ursonet.latency_rows():
            print(f"table1_latency_{r['processor']},0,"
                  f"model_ms={r['model_ms']:.0f};paper_ms={r['paper_ms']}")
    else:
        table1_ursonet.main(steps=600 if args.full else 250)
    roofline_bench.main()
    router_bench.main(n=200 if not args.full else 400)
    decode_bench.main(smoke=not args.full)
    orbit_bench.main(smoke=not args.full, check=args.check)
    coproc_bench.main(smoke=not args.full, check=args.check,
                      min_ratio=1.0 if args.check else 0.0)
    obs_bench.main(smoke=not args.full, check=args.check,
                   trace_out=args.trace)
    chaos_bench.main(smoke=not args.full, check=args.check)


if __name__ == "__main__":
    main()
