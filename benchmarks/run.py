"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows:
  * fig2_*       — Fig. 2 accelerator-throughput reproduction (cost model)
  * table1_*     — Table I UrsoNet latency (cost model) + measured
                   accuracy deltas (fp32 / PTQ / QAT / MPAI)
  * partition_*  — partition-point Pareto sweep (the paper's §IV
                   methodology, implemented)
  * micro_*      — precision-path microbenchmarks
  * roofline_*   — per-(arch x shape) roofline terms from dry-run artifacts
  * router_*     — fleet-router dispatch throughput / SLO violations /
                   failover (synthetic open-loop traffic through the
                   repro.serving facade), plus router_lm_serving:
                   engine-backed routed decode vs the windowed baseline
  * decode_*     — continuous-batching engine vs windowed baseline
                   (tokens/s, inter-token p50/p99, slot occupancy)
  * orbit_*      — orbit-aware fleet controller: eclipse-transition
                   energy cap (capped vs uncapped budget ratio) and live
                   LM pool autoscaling with graceful retirement
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer QAT training for Table I accuracy rows")
    ap.add_argument("--skip-accuracy", action="store_true",
                    help="cost-model rows only (fast CI mode)")
    args, _ = ap.parse_known_args()

    from benchmarks import (decode_bench, fig2_throughput, orbit_bench,
                            partition_sweep, precision_micro,
                            roofline_bench, router_bench, table1_ursonet)

    fig2_throughput.main()
    partition_sweep.main()
    precision_micro.main()
    if args.skip_accuracy:
        for r in table1_ursonet.latency_rows():
            print(f"table1_latency_{r['processor']},0,"
                  f"model_ms={r['model_ms']:.0f};paper_ms={r['paper_ms']}")
    else:
        table1_ursonet.main(steps=600 if args.full else 250)
    roofline_bench.main()
    router_bench.main(n=200 if not args.full else 400)
    decode_bench.main(smoke=not args.full)
    orbit_bench.main(smoke=not args.full)


if __name__ == "__main__":
    main()
