"""Decode-engine benchmark: continuous batching vs the windowed baseline.

    PYTHONPATH=src python -m benchmarks.decode_bench [--smoke] \
        [--out BENCH_decode.json] [--min-speedup 1.5]

Serves the same mixed-``max_new`` workload through the windowed
baseline and the slot-based continuous-batching engine (both built via
``repro.serving.make_server``) on two tiny configs (CPU / interpret
numbers — the *ratio* is the point: the windowed loop burns
``max(max_new)`` decode steps on every request in a window and blocks
admissions until the window drains, so its tokens/s collapses as the
``max_new`` mix widens).  Measures per run:

  * tokens/s — real sampled tokens over wall time;
  * p50/p99 inter-token latency — decode-step durations (the gap
    between consecutive tokens of any in-flight request);
  * mean slot occupancy — useful slots per decode step.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and writes the full metrics as JSON (the CI smoke step keeps
``BENCH_decode.json`` as the perf-trajectory point).  With
``--min-speedup`` the run *fails* when continuous/windowed tokens/s
falls below the bar — the CI regression tripwire for the decode path.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPT_LEN = 8


def _configs():
    from repro.configs.base import ModelConfig
    return [
        ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                    d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                    vocab_size=256, remat=False),
        ModelConfig(name="tiny-gqa", family="dense", num_layers=2,
                    d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
                    vocab_size=256, remat=False),
    ]


def _workload(n: int, max_new_hi: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN))
                             ).astype(np.int32),
             int(rng.integers(1, max_new_hi + 1)))
            for i in range(n)]


def _serve(server, workload) -> dict:
    from repro.runtime.serve import Request
    for rid, prompt, max_new in workload:
        server.submit(Request(rid, prompt, max_new=max_new))
    step_times = []
    t0 = time.perf_counter()
    c0 = time.process_time()
    while server.pending:
        s0 = time.perf_counter()
        server.step()
        step_times.append(time.perf_counter() - s0)
    cpu = time.process_time() - c0     # immune to co-tenant wall noise
    wall = time.perf_counter() - t0
    tokens = sum(len(server.done[rid].output) for rid, _, _ in workload)
    st = np.sort(np.asarray(step_times))
    return {"tokens": tokens, "wall_s": round(wall, 4),
            "cpu_s": round(cpu, 4),
            "tokens_per_s": round(tokens / cpu, 1),
            "tokens_per_wall_s": round(tokens / wall, 1),
            "steps": len(step_times),
            "intertoken_p50_ms": round(1e3 * float(st[len(st) // 2]), 3),
            "intertoken_p99_ms": round(
                1e3 * float(st[min(len(st) - 1, int(0.99 * len(st)))]), 3)}


def run_config(cfg, n_requests: int, max_new_hi: int, slots: int = 8,
               repeats: int = 3) -> dict:
    import jax
    from repro.models import transformer as T
    from repro.serving import PoolSpec, make_server

    params = T.model_init(jax.random.PRNGKey(0), cfg)
    workload = _workload(n_requests, max_new_hi)

    def fresh(kind):
        # the facade's sanctioned server constructor — the benchmark
        # builds exactly what FleetSpec-backed pools serve with
        backend = "windowed" if kind == "windowed" else "engine"
        return make_server(cfg, params, PoolSpec(
            f"bench-{kind}", ("tpu_v5e_bf16",), backend=backend,
            max_slots=slots, prompt_len=PROMPT_LEN, max_new=max_new_hi,
            block_size=8), warm=False)

    out = {"config": cfg.name, "requests": n_requests,
           "max_new_mix": [1, max_new_hi], "slots": slots}
    for kind in ("windowed", "continuous"):
        srv = fresh(kind)
        # warm every jitted program on the SAME instance (each server owns
        # its own jit wrappers), negative rids so they never collide
        warm = [(-rid - 1, p, mn)
                for rid, p, mn in _workload(slots, max_new_hi, seed=99)]
        _serve(srv, warm)
        srv.reset_stats()                 # restart telemetry post-warm
        # best-of-N: co-tenant noise on shared CI boxes only ever slows a
        # run down, so min CPU time is the honest per-step cost estimate
        best = None
        for rep in range(repeats):
            shifted = [(rid + rep * n_requests, p, mn)
                       for rid, p, mn in workload]
            res = _serve(srv, shifted)
            if best is None or res["cpu_s"] < best["cpu_s"]:
                best = res
        res = best
        if kind == "continuous":
            res["mean_occupancy"] = round(srv.stats()["mean_occupancy"], 4)
        out[kind] = res
    out["speedup_tokens_per_s"] = round(
        out["continuous"]["tokens_per_s"] / out["windowed"]["tokens_per_s"],
        3)
    return out


def main(csv: bool = True, out: str | None = None, smoke: bool = False,
         min_speedup: float = 0.0):
    n = 64 if smoke else 96
    results = [run_config(cfg, n_requests=n, max_new_hi=24,
                          repeats=3 if smoke else 4)
               for cfg in _configs()]
    if csv:
        for r in results:
            w, c = r["windowed"], r["continuous"]
            # us_per_call column is wall time like every other benchmark's
            # CSV row; the speedup/derived fields use the noise-robust
            # CPU-time tokens/s
            us = 1e6 / max(c["tokens_per_wall_s"], 1e-9)
            print(f"decode_{r['config']},{us:.1f},"
                  f"cont_tps={c['tokens_per_s']};win_tps={w['tokens_per_s']};"
                  f"speedup={r['speedup_tokens_per_s']};"
                  f"p50_ms={c['intertoken_p50_ms']};"
                  f"p99_ms={c['intertoken_p99_ms']};"
                  f"occ={c['mean_occupancy']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    for r in results:
        if min_speedup and r["speedup_tokens_per_s"] < min_speedup:
            raise SystemExit(
                f"decode perf regression: {r['config']} continuous/windowed "
                f"speedup {r['speedup_tokens_per_s']} < {min_speedup}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless continuous beats windowed by this "
                         "tokens/s factor")
    args = ap.parse_args()
    main(out=args.out, smoke=args.smoke, min_speedup=args.min_speedup)
