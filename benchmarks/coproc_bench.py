"""Co-processing benchmark: chunked paged prefill + prefill/decode
disaggregation (the MPAI DPU->VPU split) through the serving facade.

    PYTHONPATH=src python -m benchmarks.coproc_bench [--smoke] [--check] \
        [--out BENCH_coproc.json] [--min-ratio 1.0]

Three scenarios, all on prompts *longer than the engine's prompt_len
bucket* — the workload the dense-scratch prefill could not admit at all:

  * ``coproc_chunked_prefill`` — the unified engine (chunked paged
    prefill + content-hashed prefix sharing) vs the windowed baseline
    sized at the full prompt length, on a mix that shares a common
    system prefix.  The ``--check`` gate here is *correctness*: every
    output must be bit-identical to the windowed baseline's, and the
    shared prefix must actually be served from the block index
    (``prefill_tokens_computed`` << tokens offered).  Raw tokens/s is
    reported, not gated — at smoke scale the windowed loop's one wide
    fused batch prefill beats the engine's serial per-request chunk
    calls on CPU, but it hard-buckets every prompt at one compiled
    length and burns ``max(max_new)`` padded decode steps per window;
    the engine trades per-call overhead for unbounded prompts, exact
    per-request decode, and prefix reuse.

  * ``coproc_disagg_serving`` — a disaggregated two-pool fleet
    (``PoolSpec(prefill_backend="engine")``: a single-slot wide-chunk
    prefill engine — the DPU-analogue wide array — feeding the decode
    pool over mirrored paged pools) vs the unified engine pool, on an
    open-loop long-prompt mix.  Reports tokens/s for both (best-of-N
    process-CPU, same noise policy as ``decode_bench``), the handoff
    count, and the per-stage energy split (``lm.prefill`` vs ``lm``).
    Under ``--check`` the run fails unless every stream completes
    exactly (``len(tokens) == max_new``, result == stream — no token
    lost or duplicated at the handoff), the prefill stage is charged
    energy on its own pool, and disaggregated tokens/s >= ``--min-ratio``
    x unified.

  * ``coproc_sharded_serving`` — 1 prefill stage fanning versioned
    wire-format handoffs out to 2 decode shards (least-loaded import)
    vs the unified single pool, plus an untimed churn pass that retires
    a shard mid-run.  Under ``--check``: sharded outputs bit-identical
    to unified (churn included), every shard imported work, and sharded
    tokens/s >= ``--min-ratio`` x single-pool.

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks
and writes the full metrics as JSON (CI keeps ``BENCH_coproc.json`` as
the perf-trajectory point).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPT_LEN = 8          # the engine's admission bucket (chunk grid)
MAX_PROMPT = 64         # chunked prefill lifts the limit to here
MAX_NEW = 8
BLOCK = 8


def _tiny_lm():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=256, remat=False)


def _model():
    import jax

    from repro.models import transformer as T
    cfg = _tiny_lm()
    return cfg, T.model_init(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# scenario 1: chunked paged prefill vs the windowed baseline
# ---------------------------------------------------------------------------
def _shared_prefix_workload(n: int, total_len: int, shared_len: int,
                            seed: int = 0):
    """Fixed-length long prompts sharing a common system prefix (the
    prefix-sharing sweet spot: the block index serves it after the
    first request prefills it)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, 256, shared_len).astype(np.int32)
    return [(i, np.concatenate([
                prefix, rng.integers(0, 256,
                                     total_len - shared_len)
                .astype(np.int32)]),
             int(rng.integers(1, MAX_NEW + 1)))
            for i in range(n)]


def run_chunked_prefill(n_requests: int = 12, total_len: int = 64,
                        shared_len: int = 48, repeats: int = 3,
                        check: bool = False) -> dict:
    from repro.runtime.serve import Request
    from repro.serving import PoolSpec, make_server

    cfg, params = _model()
    workload = _shared_prefix_workload(n_requests, total_len, shared_len)

    def serve(srv, shift):
        for rid, prompt, max_new in workload:
            srv.submit(Request(rid + shift, prompt, max_new=max_new))
        c0 = time.process_time()
        while srv.pending:
            srv.step()
        cpu = time.process_time() - c0
        toks = sum(len(srv.done[rid + shift].output)
                   for rid, _, _ in workload)
        return toks / max(cpu, 1e-9), cpu

    engine = make_server(cfg, params, PoolSpec(
        "bench-chunked", ("tpu_v5e_bf16",), backend="engine",
        max_slots=4, prompt_len=PROMPT_LEN, max_new=MAX_NEW,
        block_size=BLOCK, max_prompt_len=MAX_PROMPT,
        prefill_chunk=2 * PROMPT_LEN))
    # the windowed baseline cannot bucket below the full prompt length
    windowed = make_server(cfg, params, PoolSpec(
        "bench-windowed", ("tpu_v5e_bf16",), backend="windowed",
        max_slots=4, prompt_len=total_len, max_new=MAX_NEW))
    # compile the long-prompt programs outside the timed region
    warm = _shared_prefix_workload(2, total_len, shared_len, seed=99)
    for srv in (engine, windowed):
        for rid, p, mn in warm:
            srv.submit(Request(-rid - 1, p, max_new=mn))
        while srv.pending:
            srv.step()
        srv.reset_stats()

    best = {}
    for rep in range(repeats):
        for kind, srv in (("engine", engine), ("windowed", windowed)):
            tps, cpu = serve(srv, (rep + 1) * 1000)
            if kind not in best or tps > best[kind][0]:
                best[kind] = (tps, cpu)
    st = engine.stats()
    mismatched = sum(
        1 for rid, _, _ in workload
        if not np.array_equal(engine.done[rid + 1000].output,
                              windowed.done[rid + 1000].output))
    out = {
        "scenario": "coproc_chunked_prefill",
        "requests": n_requests, "prompt_len": total_len,
        "shared_prefix": shared_len, "bucket": PROMPT_LEN,
        "engine_tokens_per_cpu_s": round(best["engine"][0], 1),
        "windowed_tokens_per_cpu_s": round(best["windowed"][0], 1),
        "speedup": round(best["engine"][0]
                         / max(best["windowed"][0], 1e-9), 3),
        "shared_block_hits": st["shared_block_hits"],
        "prefill_tokens_computed": st["prefill_tokens"],
        "prefill_tokens_offered": repeats * n_requests * total_len,
        "output_mismatches": mismatched,
    }
    if check:
        assert mismatched == 0, (
            f"chunked paged prefill diverged from the windowed baseline "
            f"on {mismatched}/{n_requests} outputs")
        assert st["shared_block_hits"] > 0, \
            "prefix sharing never hit on a shared-prefix workload"
        assert (out["prefill_tokens_computed"]
                < out["prefill_tokens_offered"]), \
            "prefix sharing saved no prefill compute"
    return out


# ---------------------------------------------------------------------------
# scenario 2: disaggregated two-pool fleet vs the unified engine pool
# ---------------------------------------------------------------------------
def _pool_spec(disagg: bool, slots: int):
    from repro.serving import PoolSpec
    kw = {}
    if disagg:
        # the prefill-class engine is the wide DPU analogue: one fused
        # chunk spans the whole padded prompt, vs the unified engine's
        # per-bucket chunk dispatches
        kw = dict(prefill_backend="engine", prefill_chunk=MAX_PROMPT)
    return PoolSpec("lm", ("tpu_v5e_bf16",), backend="engine", capacity=1,
                    max_window=4 * slots, max_wait_s=0.0, max_slots=slots,
                    prompt_len=PROMPT_LEN, max_new=MAX_NEW,
                    block_size=BLOCK, max_prompt_len=MAX_PROMPT, **kw)


def run_disagg_serving(n_requests: int = 24, repeats: int = 3,
                       slots: int = 4, seed: int = 0,
                       check: bool = False, min_ratio: float = 0.0) -> dict:
    from repro.router.slo import SLOClass
    from repro.serving import FleetSpec, LMWork
    from repro.serving.traffic import open_loop

    cfg, params = _model()
    relaxed = SLOClass("lm-offline", max_latency_s=600.0)

    def payload(rng):
        n = int(rng.integers(3 * PROMPT_LEN, MAX_PROMPT - MAX_NEW + 1))
        return LMWork(rng.integers(0, 256, n).astype("int32"),
                      max_new=int(rng.integers(1, MAX_NEW + 1)))

    out = {"scenario": "coproc_disagg_serving", "requests": n_requests,
           "repeats": repeats, "slots": slots,
           "prompt_mix": [3 * PROMPT_LEN, MAX_PROMPT - MAX_NEW]}
    clients = {}
    for kind in ("unified", "disagg"):
        spec = FleetSpec(pools=[_pool_spec(kind == "disagg", slots)],
                         workload="transformer", seq_len=PROMPT_LEN)
        clients[kind] = spec.build(model=(cfg, params))
    best_tps = {"unified": 0.0, "disagg": 0.0}
    handles_by = {"unified": [], "disagg": []}
    # interleave the repeats so co-tenant drift on a shared box hits
    # both architectures alike (best-of-N per arch, process-CPU basis)
    for rep in range(repeats):
        for kind, client in clients.items():
            c0 = time.process_time()
            hs = open_loop(client, [relaxed], [1.0], rate_hz=2000.0,
                           n_requests=n_requests, seed=seed + rep,
                           dt=0.05, payload_fn=payload)
            cpu = time.process_time() - c0
            toks = sum(len(h.tokens) for h in hs)
            best_tps[kind] = max(best_tps[kind], toks / max(cpu, 1e-9))
            handles_by[kind].extend(hs)
            if check:
                assert not hs.truncated, \
                    f"{kind}: open_loop trace truncated — metrics invalid"
    for kind, client in clients.items():
        pools = client.telemetry["pools"]
        row = {"tokens_per_cpu_s": round(best_tps[kind], 1),
               "pools": {name: {"energy_j": p["energy_j"],
                                "prefill_tokens": p["prefill_tokens"],
                                "decode_tokens": p["decode_tokens"],
                                "tokens_generated": p["tokens_generated"]}
                         for name, p in pools.items()}}
        if kind == "disagg":
            srv = client.engines["lm"]
            row["handoffs"] = srv.stats()["handoffs"]
        out[kind] = row
    out["ratio_disagg_vs_unified"] = round(
        out["disagg"]["tokens_per_cpu_s"]
        / max(out["unified"]["tokens_per_cpu_s"], 1e-9), 3)

    if check:
        # exact stream completeness, both architectures: every admitted
        # stream finishes with exactly its max_new tokens, and the
        # streamed view equals the terminal result — for the disagg
        # fleet that means no token lost or duplicated crossing the
        # prefill->decode seam
        for kind, handles in handles_by.items():
            for h in handles:
                assert h.done and h.admitted, \
                    f"{kind} rid {h.rid} incomplete"
                r = h.result()
                assert list(r.tokens) == h.tokens, \
                    f"{kind} rid {h.rid}: stream/result mismatch"
                assert len(h.tokens) == h._work.max_new, \
                    (kind, h.rid, len(h.tokens), h._work.max_new)
        for h in handles_by["disagg"]:
            assert h.telemetry["prefill_pool"] == "lm.prefill"
        pools = out["disagg"]["pools"]
        assert pools["lm.prefill"]["energy_j"] > 0, \
            "prefill stage spent no energy on its own pool"
        assert pools["lm.prefill"]["prefill_tokens"] > 0
        assert pools["lm"]["prefill_tokens"] == 0, \
            "prompt tokens leaked onto the decode pool's counters"
        assert out["disagg"]["handoffs"] >= n_requests * repeats
        if min_ratio:
            assert out["ratio_disagg_vs_unified"] >= min_ratio, (
                f"disaggregated fleet fell behind unified: "
                f"{out['ratio_disagg_vs_unified']} < {min_ratio}")
    return out


# ---------------------------------------------------------------------------
# scenario 3: N-way sharded decode fan-out vs the unified single pool
# ---------------------------------------------------------------------------
def run_sharded_serving(n_requests: int = 16, repeats: int = 3,
                        slots: int = 4, shards: int = 2, seed: int = 0,
                        check: bool = False,
                        min_ratio: float = 0.0) -> dict:
    """1 prefill stage fanning wire-format handoffs out to ``shards``
    decode shards, vs the unified single-pool engine, on the same
    long-prompt workload.  Best-of-N process-CPU tokens/s per
    architecture; a final *churn* pass retires a decode shard mid-run
    and must complete every stream regardless.  Under ``--check`` the
    sharded outputs must be bit-identical to the unified pool's (churn
    pass included) and sharded tokens/s must be >= ``--min-ratio`` x
    unified."""
    from repro.runtime.serve import Request
    from repro.serving import PoolSpec, make_server

    cfg, params = _model()
    rng = np.random.default_rng(seed)
    workload = [(i, rng.integers(0, 256,
                                 int(rng.integers(3 * PROMPT_LEN,
                                                  MAX_PROMPT - MAX_NEW + 1)))
                 .astype(np.int32),
                 int(rng.integers(1, MAX_NEW + 1)))
                for i in range(n_requests)]

    def build(n_shards):
        # disagg arms use the wide fused prefill chunk (the DPU
        # analogue's scheduling win, same as scenario 2); the
        # bit-identity gate therefore compares sharded against the
        # 1-shard seam with IDENTICAL chunk geometry — sharding must
        # change placement, never tokens — while the perf ratio gates
        # against the unified single pool
        kw = ({} if n_shards == 0 else
              dict(prefill_backend="engine", prefill_chunk=MAX_PROMPT,
                   decode_shards=n_shards))
        return make_server(cfg, params, PoolSpec(
            "bench-sharded", ("tpu_v5e_bf16",), backend="engine",
            max_slots=slots, prompt_len=PROMPT_LEN, max_new=MAX_NEW,
            block_size=BLOCK, max_prompt_len=MAX_PROMPT, **kw))

    single = build(0)                   # unified engine pool
    sharded = build(shards)             # 1 prefill -> N decode shards
    seam_ref = build(1)                 # unsharded seam, same geometry

    def serve(srv, shift):
        for rid, prompt, max_new in workload:
            srv.submit(Request(rid + shift, prompt, max_new=max_new))
        c0 = time.process_time()
        while srv.pending:
            srv.step()
        cpu = time.process_time() - c0
        toks = sum(len(srv.done[rid + shift].output)
                   for rid, _, _ in workload)
        return toks / max(cpu, 1e-9)

    # compile outside the timed region
    warm = _shared_prefix_workload(2, MAX_PROMPT - MAX_NEW, PROMPT_LEN,
                                   seed=99)
    for srv in (single, sharded, seam_ref):
        for rid, p, mn in warm:
            srv.submit(Request(-rid - 1, p, max_new=mn))
        while srv.pending:
            srv.step()
        srv.reset_stats()

    best = {"single": 0.0, "sharded": 0.0}
    for rep in range(repeats):          # interleaved best-of-N
        for kind, srv in (("single", single), ("sharded", sharded)):
            best[kind] = max(best[kind], serve(srv, (rep + 1) * 1000))
    serve(seam_ref, 1000)               # untimed bit-identity reference

    # churn pass (untimed): retire a decode shard while its streams are
    # mid-decode; the draining shard finishes what it holds, new imports
    # fan out over the survivors, and nothing is dropped
    churn_shift = (repeats + 1) * 1000
    for rid, prompt, max_new in workload:
        sharded.submit(Request(rid + churn_shift, prompt, max_new=max_new))
    for _ in range(3):
        sharded.step()
    sharded.retire_shard(shards - 1)
    while sharded.pending:
        sharded.step()

    st = sharded.stats()
    out = {
        "scenario": "coproc_sharded_serving",
        "requests": n_requests, "repeats": repeats, "slots": slots,
        "decode_shards": shards,
        "single_tokens_per_cpu_s": round(best["single"], 1),
        "sharded_tokens_per_cpu_s": round(best["sharded"], 1),
        "ratio_sharded_vs_single": round(
            best["sharded"] / max(best["single"], 1e-9), 3),
        "handoffs": st["handoffs"],
        "imports_by_shard": st["imports_by_shard"],
        "seam_deferrals_by_shard": st["seam_deferrals_by_shard"],
    }
    if check:
        # bit-identical to the unsharded seam (same chunk geometry),
        # every repeat AND the churn pass — sharding and mid-run
        # retirement change placement, never tokens
        for rid, _, max_new in workload:
            want = seam_ref.done[rid + 1000].output
            for rep in range(repeats):
                got = sharded.done[rid + (rep + 1) * 1000].output
                assert np.array_equal(got, want), \
                    f"shard divergence: rid {rid} rep {rep}"
            got = sharded.done[rid + churn_shift].output
            assert len(got) == max_new and np.array_equal(got, want), \
                f"churn pass dropped/diverged: rid {rid}"
        assert all(st["imports_by_shard"].values()), \
            f"a decode shard imported nothing: {st['imports_by_shard']}"
        if min_ratio:
            assert out["ratio_sharded_vs_single"] >= min_ratio, (
                f"sharded fleet fell behind the single pool: "
                f"{out['ratio_sharded_vs_single']} < {min_ratio}")
    return out


def main(csv: bool = True, out: str | None = None, smoke: bool = False,
         check: bool = False, min_ratio: float = 0.0):
    results = [
        run_chunked_prefill(n_requests=8 if smoke else 16,
                            repeats=2 if smoke else 3, check=check),
        # keep 3 repeats even in smoke: the disagg-vs-unified ratio is
        # a best-of-N CPU-time gate and needs the extra sample against
        # co-tenant noise
        run_disagg_serving(n_requests=16 if smoke else 32,
                           repeats=3, check=check, min_ratio=min_ratio),
        run_sharded_serving(n_requests=12 if smoke else 24,
                            repeats=3, check=check, min_ratio=min_ratio),
    ]
    if csv:
        r = results[0]
        us = 1e6 / max(r["engine_tokens_per_cpu_s"], 1e-9)
        print(f"{r['scenario']},{us:.1f},"
              f"eng_tps={r['engine_tokens_per_cpu_s']};"
              f"win_tps={r['windowed_tokens_per_cpu_s']};"
              f"speedup={r['speedup']};"
              f"shared_hits={r['shared_block_hits']};"
              f"mismatches={r['output_mismatches']}")
        r = results[1]
        us = 1e6 / max(r["disagg"]["tokens_per_cpu_s"], 1e-9)
        print(f"{r['scenario']},{us:.1f},"
              f"disagg_tps={r['disagg']['tokens_per_cpu_s']};"
              f"unified_tps={r['unified']['tokens_per_cpu_s']};"
              f"ratio={r['ratio_disagg_vs_unified']};"
              f"handoffs={r['disagg']['handoffs']};"
              f"prefill_energy_j="
              f"{r['disagg']['pools']['lm.prefill']['energy_j']}")
        r = results[2]
        us = 1e6 / max(r["sharded_tokens_per_cpu_s"], 1e-9)
        imports = ";".join(f"{k}={v}"
                           for k, v in sorted(r["imports_by_shard"].items()))
        print(f"{r['scenario']},{us:.1f},"
              f"sharded_tps={r['sharded_tokens_per_cpu_s']};"
              f"single_tps={r['single_tokens_per_cpu_s']};"
              f"ratio={r['ratio_sharded_vs_single']};"
              f"handoffs={r['handoffs']};{imports}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--check", action="store_true",
                    help="fail on completeness/equality/energy-split "
                         "violations (and --min-ratio, if set)")
    ap.add_argument("--min-ratio", type=float, default=0.0,
                    help="with --check: fail unless disaggregated "
                         "tokens/s >= ratio x unified")
    args = ap.parse_args()
    main(out=args.out, smoke=args.smoke, check=args.check,
         min_ratio=args.min_ratio)
