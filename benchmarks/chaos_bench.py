"""Radiation-hardening chaos benchmark: overhead gate + seeded campaign.

    PYTHONPATH=src python -m benchmarks.chaos_bench [--smoke] [--check] \
        [--out BENCH_chaos.json]

Two scenarios:

``chaos_overhead`` — the same engine-backed fleet serves the same
seeded workload with the hardening layer off and on (no faults),
interleaved best-of-N on both a process-CPU and a decode-wall basis
(the noise policy of ``decode_bench`` / ``obs_bench``).  Hardening buys
per-block integrity digests, the fused decode-path verify, and the
background scrub pass; the gate says that insurance must cost under
``--max-overhead`` (default 3%) of decode tokens/s — and that the
hardened arm's outputs are *bit-identical* to hardening-off, so the
layer is pure observation until an upset actually lands.

``chaos_campaign`` — a seeded randomized SEU campaign over a two-pool
fleet (one unified hardened engine pool, one disaggregated
prefill->decode pool) under open-loop traffic, with the orbit storm
ladder attached: one ``kv_bitflip``, one ``slot_stall``, one
``handoff_loss``, and one transient control-plane pool fault, all at
seed-jittered times.  Under ``--check`` the campaign fails unless:

  * **exactly-once accounting** — ``admitted == completed + dropped``
    and every drop carries a reason code;
  * **zero corrupted tokens** — every completed request's final tokens
    bit-match a clean (fault-free) run of the same seeded workload,
    recovery and failover included;
  * **detection fired** — each injected fault class shows up in
    telemetry (bitflip detected + block quarantined, watchdog trip,
    handoff replayed, failover + retry);
  * **no open chains** — the flight recorder closes every request span
    chain and no engine lane span leaks.

Results (including the storm ladder's pressure trace) land in
``BENCH_chaos.json`` for the CI artifact trail.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPT_LEN = 8
MAX_NEW = 6
BLOCK = 4
# the overhead gate decodes much longer sequences than the campaign:
# each timed pass needs enough decode steps that the on/off wall ratio
# measures the fused verify, not scheduler jitter on ~ms passes
OVERHEAD_MAX_NEW = 48


def _model():
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    cfg = ModelConfig(name="tiny-mha", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                      vocab_size=256, remat=False)
    return cfg, T.model_init(jax.random.PRNGKey(0), cfg)


def _overhead_model():
    """Overhead-gate model: big enough that a decode step is real work
    (~ms), not dispatch overhead.  On the tiny campaign model the
    full-pool checksum sweep rivals the forward pass itself and the
    gate would measure the model, not the hardening layer; at
    ``d_model=256`` the compute-to-pool ratio is in the regime any
    deployment-sized config lives in."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    cfg = ModelConfig(name="small-mha", family="dense", num_layers=2,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=256, remat=False)
    return cfg, T.model_init(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# scenario 1: hardening overhead (no faults)
# ---------------------------------------------------------------------------
def _overhead_fleet(harden: bool, slots: int):
    from repro.serving import FleetSpec, PoolSpec
    return FleetSpec(
        pools=[PoolSpec("lm", ("tpu_v5e_bf16",), backend="engine",
                        capacity=1, max_window=slots, max_wait_s=0.0,
                        max_slots=slots, prompt_len=PROMPT_LEN,
                        max_new=OVERHEAD_MAX_NEW, block_size=BLOCK,
                        harden=harden)],
        workload="transformer", seq_len=PROMPT_LEN)


def _serve_once(client, n_requests: int, seed: int):
    """One timed pass; returns (tokens/cpu-s, decode tokens/wall-s,
    {rid-order index: tokens})."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, int(rng.integers(2, PROMPT_LEN + 1)))
               .astype(np.int32) for _ in range(n_requests)]
    pc = client.router.telemetry.pools["lm"]
    d_tok0, d_s0 = pc.decode_tokens, pc.decode_s
    c0 = time.process_time()
    handles = [client.submit(p, slo="offline", max_new=OVERHEAD_MAX_NEW)
               for p in prompts]
    client.drain()
    cpu = time.process_time() - c0
    toks = {i: tuple(h.tokens) for i, h in enumerate(handles)}
    decode_tps = ((pc.decode_tokens - d_tok0)
                  / max(pc.decode_s - d_s0, 1e-9))
    return sum(map(len, toks.values())) / max(cpu, 1e-9), decode_tps, toks


def run_overhead(n_requests: int = 24, repeats: int = 9, slots: int = 4,
                 seed: int = 0, check: bool = False,
                 max_overhead: float = 0.03) -> dict:
    model = _overhead_model()
    clients = {k: _overhead_fleet(k == "on", slots).build(model=model)
               for k in ("off", "on")}
    best_cpu = {"off": 0.0, "on": 0.0}
    best_dec = {"off": 0.0, "on": 0.0}
    outputs = {"off": {}, "on": {}}
    # interleave the repeats so co-tenant drift on a shared box hits
    # both arms alike; the gate ratio is the MEDIAN of per-pair
    # (off, on back-to-back) ratios — within a pair the drift is the
    # same for both arms, and the median shrugs off the pairs a noise
    # spike still lands inside (best-of-N of each arm independently
    # wobbled several % on a loaded box because a clean multi-second
    # window for one arm need not exist for the other)
    pair_overheads = []
    for rep in range(repeats):
        pair_dec = {}
        for kind, client in clients.items():
            cpu_tps, dec_tps, toks = _serve_once(client, n_requests,
                                                 seed + rep)
            best_cpu[kind] = max(best_cpu[kind], cpu_tps)
            best_dec[kind] = max(best_dec[kind], dec_tps)
            pair_dec[kind] = dec_tps
            outputs[kind][rep] = toks
        pair_overheads.append(1.0 - pair_dec["on"]
                              / max(pair_dec["off"], 1e-9))
    overhead = float(np.median(pair_overheads))
    bit_identical = outputs["on"] == outputs["off"]
    eng = clients["on"].engines["lm"]
    out = {
        "scenario": "chaos_overhead",
        "requests_per_rep": n_requests, "repeats": repeats,
        "slots": slots, "max_new": OVERHEAD_MAX_NEW,
        "off_decode_tokens_per_s": round(best_dec["off"], 1),
        "on_decode_tokens_per_s": round(best_dec["on"], 1),
        "off_tokens_per_cpu_s": round(best_cpu["off"], 1),
        "on_tokens_per_cpu_s": round(best_cpu["on"], 1),
        "overhead": round(overhead, 4),
        "max_overhead": max_overhead,
        "bit_identical": bit_identical,
        "scrubbed_blocks": eng.scrubbed_blocks,
    }
    if check:
        assert bit_identical, \
            "hardened no-fault outputs differ from hardening-off"
        assert eng.bitflips_detected == 0, "phantom detection with no SEU"
        assert overhead <= max_overhead, (
            f"hardening overhead {overhead:.1%} exceeds the "
            f"{max_overhead:.0%} decode-tokens/s gate (off "
            f"{best_dec['off']:.0f} vs on {best_dec['on']:.0f} tok/s)")
    return out


# ---------------------------------------------------------------------------
# scenario 2: seeded randomized SEU campaign
# ---------------------------------------------------------------------------
def _campaign_fleet(seed: int, faulted: bool):
    from repro.serving import FaultSpec, FleetSpec, PoolSpec
    pools = [
        PoolSpec("vpu", ("tpu_v5e_bf16",), backend="engine", capacity=1,
                 max_window=4, max_wait_s=0.0, max_slots=3,
                 prompt_len=PROMPT_LEN, max_new=MAX_NEW, block_size=BLOCK,
                 harden=True, watchdog_steps=3),
        PoolSpec("dpu", ("tpu_v5e_bf16",), backend="engine", capacity=1,
                 max_window=4, max_wait_s=0.0, max_slots=3,
                 prompt_len=PROMPT_LEN, max_new=MAX_NEW, block_size=BLOCK,
                 max_prompt_len=2 * PROMPT_LEN, prefill_backend="engine",
                 harden=True, watchdog_steps=3),
    ]
    faults = []
    if faulted:
        rng = np.random.default_rng(seed)
        t = lambda lo, hi: round(float(rng.uniform(lo, hi)), 4)  # noqa: E731
        faults = [
            FaultSpec("vpu", at_s=t(0.001, 0.01), kind="kv_bitflip",
                      seed=int(rng.integers(1 << 16))),
            # stall the disagg pool's decode slot: under open-loop load
            # it is saturated through this window, so the latched fault
            # is guaranteed to sit under a live request until the
            # watchdog trips it (an empty stalled slot trips nothing —
            # correctly)
            FaultSpec("dpu", at_s=t(0.01, 0.05), duration_s=0.3,
                      kind="slot_stall", slot=0),
            FaultSpec("dpu", at_s=t(0.001, 0.02), kind="handoff_loss"),
            FaultSpec("vpu", at_s=t(0.05, 0.1), duration_s=0.2,
                      kind="pool"),
        ]
    return FleetSpec(pools=pools, workload="transformer",
                     seq_len=PROMPT_LEN, trace=faulted, faults=faults)


def _run_campaign_arm(seed: int, n_requests: int, faulted: bool,
                      model) -> tuple:
    from repro.orbit import OrbitSpec, PhaseSpec
    from repro.router import SLOClass
    from repro.serving import LMWork, open_loop
    client = _campaign_fleet(seed, faulted).build(model=model)
    ctrl = None
    if faulted:
        # storm ladder: hardening-event pressure floors the mode at
        # conserve even though the battery never runs low
        ctrl = OrbitSpec(phases=[PhaseSpec("sunlit", 1e4, 1e6)],
                         bucket_j=1e6, storm_events=1).attach(client)
    rng = np.random.default_rng(seed + 1)

    def payload(prng):
        return LMWork(prng.integers(0, 256, int(prng.integers(
            2, PROMPT_LEN + 1))).astype(np.int32), max_new=MAX_NEW)

    handles = open_loop(client, [SLOClass("offline", max_latency_s=600.0)],
                        [1.0], rate_hz=400.0, n_requests=n_requests,
                        seed=int(rng.integers(1 << 16)), dt=0.002,
                        payload_fn=payload)
    client.drain()
    return client, ctrl, {h.rid: (tuple(h.tokens), h.dropped)
                          for h in handles}


def run_campaign(n_requests: int = 32, seed: int = 7,
                 check: bool = False) -> dict:
    model = _model()
    _, _, clean = _run_campaign_arm(seed, n_requests, False, model)
    client, ctrl, chaos = _run_campaign_arm(seed, n_requests, True, model)
    snap = client.router.telemetry.snapshot()
    pools = snap["pools"]
    corrupted = [rid for rid, (toks, dropped) in chaos.items()
                 if not dropped and toks != clean[rid][0]]
    dropped = [rid for rid, (_, d) in chaos.items() if d]
    tr = client.tracer
    events = {
        "bitflips_detected": snap["bitflips_detected"],
        "blocks_quarantined": snap["blocks_quarantined"],
        "watchdog_trips": sum(p["watchdog_trips"] for p in pools.values()),
        "handoffs_replayed": snap["handoffs_replayed"],
        "failovers": snap["failovers"],
        "retries": snap["retries"],
    }
    out = {
        "scenario": "chaos_campaign",
        "requests": n_requests, "seed": seed,
        "admitted": snap["admitted"], "completed": snap["completed"],
        "dropped": snap["dropped"],
        "drops_by_reason": snap["drops_by_reason"],
        "corrupted_tokens": len(corrupted),
        "events": events,
        "storm_pressure_peak": (None if ctrl is None else
                                round(max(
                                    (abs(p) for p in [ctrl.storm_pressure]),
                                    default=0.0), 4)),
        "mode_transitions": [] if ctrl is None else [
            {"t": t, "mode": m} for t, m in ctrl.transitions],
        "open_spans": len(tr.open_spans()),
    }
    if check:
        assert not corrupted, \
            f"corrupted final tokens on requests {corrupted[:5]}"
        assert snap["admitted"] == snap["completed"] + snap["dropped"], \
            (snap["admitted"], snap["completed"], snap["dropped"])
        assert len(dropped) == snap["dropped"]
        reasoned = sum(snap["drops_by_reason"].values())
        assert reasoned >= snap["dropped"], "drop without a reason code"
        for name, n in events.items():
            assert n >= 1, f"fault campaign never exercised {name}"
        assert not tr.open_spans(), \
            f"orphan spans after drain: {tr.open_spans()}"
        assert all(tr.closed(rid) for rid in tr.request_ids), \
            "a traced request never saw a terminal outcome"
        assert any(m == "conserve" for _, m in (ctrl.transitions or [])), \
            "storm pressure never floored the mode"
    return out


def main(csv: bool = True, out: str | None = None, smoke: bool = False,
         check: bool = False, max_overhead: float = 0.03,
         seed: int = 7):
    results = [
        # keep 9 repeats even in smoke: the overhead gate is a
        # best-of-N ratio and needs the samples against noise
        run_overhead(n_requests=16 if smoke else 32, repeats=9,
                     check=check, max_overhead=max_overhead),
        run_campaign(n_requests=24 if smoke else 48, seed=seed,
                     check=check),
    ]
    if csv:
        r = results[0]
        us = 1e6 / max(r["on_decode_tokens_per_s"], 1e-9)
        print(f"{r['scenario']},{us:.1f},"
              f"off_tps={r['off_decode_tokens_per_s']};"
              f"on_tps={r['on_decode_tokens_per_s']};"
              f"overhead={r['overhead']};"
              f"bit_identical={r['bit_identical']}")
        c = results[1]
        ev = c["events"]
        print(f"{c['scenario']},0,"
              f"admitted={c['admitted']};completed={c['completed']};"
              f"dropped={c['dropped']};corrupted={c['corrupted_tokens']};"
              f"bitflips={ev['bitflips_detected']};"
              f"trips={ev['watchdog_trips']};"
              f"handoffs_replayed={ev['handoffs_replayed']};"
              f"retries={ev['retries']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write full JSON here")
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--check", action="store_true",
                    help="fail on overhead > --max-overhead, corrupted "
                         "tokens, accounting drift, a fault class that "
                         "never fired, or an open trace chain")
    ap.add_argument("--max-overhead", type=float, default=0.03,
                    help="with --check: max hardened decode tokens/s "
                         "loss vs hardening-off (fraction; default 0.03)")
    ap.add_argument("--seed", type=int, default=7,
                    help="campaign seed (fault times + sites + traffic)")
    args = ap.parse_args()
    main(out=args.out, smoke=args.smoke, check=args.check,
         max_overhead=args.max_overhead, seed=args.seed)
