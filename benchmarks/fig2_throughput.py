"""Fig. 2 reproduction: inference FPS of MobileNetV2 / ResNet-50 /
InceptionV4 on Edge TPU vs MyriadX VPU (plus DPU / v5e for context),
derived from the roofline cost model over the analytic conv tables.

The paper's qualitative claims to reproduce:
  * MobileNetV2: TPU ~8x the VPU's FPS (small net, fits TPU SRAM)
  * ResNet-50:   VPU ~2x the TPU (TPU spills weights over USB/DDR)
  * InceptionV4: both ~10 FPS
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.accelerators import PROFILES
from repro.core.cost_model import fps, layer_costs_from_convspecs
from repro.models.cnn import (inception_v4_layers, mobilenet_v2_layers,
                              resnet50_layers)

NETS = {
    "mobilenet_v2": mobilenet_v2_layers,
    "resnet50": resnet50_layers,
    "inception_v4": inception_v4_layers,
}

DEVICES = ["edge_tpu", "myriadx_vpu", "mpsoc_dpu", "tpu_v5e_int8"]


def _edge_tpu_effective(layers):
    """Edge TPU has ~8 MB on-chip SRAM: models whose weights fit stream
    at full rate; larger models re-fetch weights over the slow host link
    per inference (the USB/DDR spill the paper's Fig. 2 shows)."""
    prof = PROFILES["edge_tpu"]
    wbytes = sum(l.weight_elems for l in layers)          # int8 weights
    if wbytes <= 7.5e6:
        return dataclasses.replace(prof, mem_bw=30e9)     # SRAM-resident
    return dataclasses.replace(prof, weight_bw=0.3e9)     # USB/DDR refetch


def rows():
    out = []
    for net, fn in NETS.items():
        layers = layer_costs_from_convspecs(fn())
        for dev in DEVICES:
            prof = (_edge_tpu_effective(layers) if dev == "edge_tpu"
                    else PROFILES[dev])
            out.append({"net": net, "device": dev,
                        "fps": round(fps(layers, prof), 2)})
    return out


def main(csv=True):
    t0 = time.perf_counter()
    rs = rows()
    us = (time.perf_counter() - t0) * 1e6 / len(rs)
    by = {(r["net"], r["device"]): r["fps"] for r in rs}
    derived = (f"mobilenet TPU/VPU={by[('mobilenet_v2', 'edge_tpu')] / by[('mobilenet_v2', 'myriadx_vpu')]:.1f}x;"
               f" resnet50 VPU/TPU={by[('resnet50', 'myriadx_vpu')] / by[('resnet50', 'edge_tpu')]:.1f}x;"
               f" inceptionv4 TPU={by[('inception_v4', 'edge_tpu')]:.1f}fps"
               f" VPU={by[('inception_v4', 'myriadx_vpu')]:.1f}fps")
    if csv:
        for r in rs:
            print(f"fig2_{r['net']}_{r['device']},{us:.1f},fps={r['fps']}")
        print(f"fig2_summary,{us:.1f},{derived}")
    return rs, derived


if __name__ == "__main__":
    main()
