"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

One row per (arch x shape x mesh): the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.  This is a
*reader* — the numbers come from compiled dry-runs (launch/dryrun.py)."""
from __future__ import annotations

import glob
import json
import os
import time

HEADER = ("arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_ratio,roofline_frac")


def rows(dirpath: str = "experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        r = d.get("roofline")
        if r:
            r = dict(r)
            r["tag"] = os.path.basename(path)[:-5]
            out.append(r)
    return out


def main(csv: bool = True, dirpath: str = "experiments/dryrun"):
    t0 = time.perf_counter()
    rs = rows(dirpath)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rs), 1)
    if not rs:
        print(f"roofline_table,{us:.0f},no dry-run artifacts in {dirpath} "
              f"(run python -m repro.launch.dryrun --all first)")
        return []
    if csv:
        for r in rs:
            print(f"roofline_{r['tag']},{us:.0f},"
                  f"c/m/x_ms={r['compute_ms']}/{r['memory_ms']}/"
                  f"{r['collective_ms']};dominant={r['dominant']};"
                  f"useful={r['useful_ratio']};frac={r['roofline_frac']}")
    return rs


if __name__ == "__main__":
    main()
