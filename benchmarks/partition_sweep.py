"""Partition-point trade-off sweep (the paper's implicit design space,
named as future work in §IV): for every cut of UrsoNet across the
DPU(INT8)+VPU(FP16) pair, report latency / energy / accuracy-penalty and
mark the Pareto frontier — the 'methodology and design guidelines for
model partitioning and accelerator selection' the paper calls for.

Also sweeps one assigned LM arch (qwen3-14b, serve shape) over TPU v5e
int8/bf16 operating points, demonstrating the same machinery drives the
pod-scale deployment."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.cost_model import (layer_costs_from_convspecs,
                                   transformer_layer_costs)
from repro.core.scheduler import pareto_frontier, schedule
from repro.models.cnn import ursonet_table1_layers


def ursonet_sweep():
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    plans = schedule(layers, ["mpsoc_dpu", "myriadx_vpu"],
                     accuracy_penalty={"mpsoc_dpu": 0.08})
    return plans


def lm_sweep(arch: str = "qwen3-14b", seq: int = 4096):
    cfg = get_config(arch)
    layers = transformer_layer_costs(cfg, seq)
    plans = schedule(layers, ["tpu_v5e_int8", "tpu_v5e_bf16"],
                     accuracy_penalty={"tpu_v5e_int8": 0.08},
                     cut_candidates=list(range(4, cfg.num_layers, 4)))
    return plans


def main(csv: bool = True):
    t0 = time.perf_counter()
    up = ursonet_sweep()
    lp = lm_sweep()
    us = (time.perf_counter() - t0) * 1e6 / max(len(up) + len(lp), 1)
    if csv:
        for i, p in enumerate(up):
            segs = ";".join(f"{s}-{e}@{d}" for s, e, d in p.assignments)
            print(f"partition_ursonet_{i},{us:.0f},lat_ms={p.latency_s*1e3:.1f}"
                  f";energy_j={p.energy_j:.3f};acc_pen={p.accuracy_penalty:.3f}"
                  f";plan={segs}")
        for i, p in enumerate(lp):
            segs = ";".join(f"{s}-{e}@{d}" for s, e, d in p.assignments)
            print(f"partition_qwen3_{i},{us:.0f},lat_ms={p.latency_s*1e3:.2f}"
                  f";acc_pen={p.accuracy_penalty:.3f};plan={segs}")
    return up, lp


if __name__ == "__main__":
    main()
