"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json artifacts.

    PYTHONPATH=src python tools/make_experiments.py > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath="experiments/dryrun"):
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        d["tag"] = os.path.basename(p)[:-5]
        out.append(d)
    return out


def gb(x):
    return f"{(x or 0) / 1e9:.2f}"


def dryrun_table(rows):
    print("| arch | shape | mesh | plan | compile s | args GB/dev | "
          "temp GB/dev | collectives (count) | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        tag_bits = d["tag"].split("__")
        plan = tag_bits[3] if len(tag_bits) > 3 else "bf16"
        cc = d.get("collective_counts", {})
        ccs = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | {plan} | "
              f"{d.get('compile_s', '-')} | "
              f"{gb(d.get('argument_size_in_bytes'))} | "
              f"{gb(d.get('temp_size_in_bytes'))} | {ccs} | "
              f"{gb(d.get('collective_bytes'))} |")


def roofline_table(rows, mesh="16x16"):
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | model GF | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        r = d.get("roofline")
        if not r or d["mesh"] != mesh:
            continue
        if len(d["tag"].split("__")) > 3:      # plan variants listed in §Perf
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
              f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
              f"{r['model_gflops']} | {r['useful_ratio']} | "
              f"{r['roofline_frac']} |")


if __name__ == "__main__":
    rows = load("experiments/dryrun")
    if os.path.isdir("experiments/dryrun_multi"):
        rows += load("experiments/dryrun_multi")
    print("### Dry-run table\n")
    dryrun_table(rows)
    print("\n### Roofline table (single-pod 16x16, 256 chips)\n")
    roofline_table(rows)
