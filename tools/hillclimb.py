import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run named (cell x variant) experiments and
write artifacts to experiments/perf/<tag>.json.

Each variant is hypothesis-driven (see EXPERIMENTS.md §Perf); the driver
just makes the measurement reproducible:

    PYTHONPATH=src python tools/hillclimb.py <experiment> [...]
    PYTHONPATH=src python tools/hillclimb.py --list
"""
import argparse
import json
import sys
import time

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import analyze, lower_cell, probe_costs, roofline_row
from repro.launch.mesh import make_production_mesh, production_mesh_config


def run(tag, arch, shape_name, *, multi_pod=False, plan="bf16",
        probe=True, tc=None, **overrides):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_cfg = production_mesh_config(multi_pod=multi_pod)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, mesh_cfg, plan, tc=tc)
    stats = analyze(lowered, cfg, shape, mesh_cfg)
    if probe:
        p = probe_costs(cfg, shape, mesh, mesh_cfg, plan, tc=tc)
        stats["scanned_raw"] = {k: stats.get(k) for k in
                                ("flops", "hlo_bytes", "collective_bytes")}
        stats.update(p)
        stats["roofline"] = roofline_row(cfg, shape, mesh_cfg, stats)
    stats["variant"] = tag
    stats["overrides"] = {k: str(v) for k, v in overrides.items()}
    stats["plan"] = plan
    stats["wall_s"] = round(time.time() - t0, 1)
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{tag}.json", "w") as f:
        json.dump(stats, f, indent=1)
    r = stats["roofline"]
    print(f"{tag}: c/m/x = {r['compute_ms']}/{r['memory_ms']}/"
          f"{r['collective_ms']} ms dominant={r['dominant']} "
          f"frac={r['roofline_frac']} useful={r['useful_ratio']} "
          f"temp/dev={(stats.get('temp_size_in_bytes') or 0)/1e9:.1f}GB",
          flush=True)
    return stats


EXPERIMENTS = {
    # cell A: llama3-405b train_4k (worst roofline fraction; memory/compute)
    "llama_base": lambda: run("llama_base", "llama3-405b", "train_4k",
                              multi_pod=True),
    "llama_flat_remat": lambda: run("llama_flat_remat", "llama3-405b",
                                    "train_4k", multi_pod=True,
                                    remat_group=0),
    "llama_g18": lambda: run("llama_g18", "llama3-405b", "train_4k",
                             multi_pod=True, remat_group=18),
    "llama_accum16": lambda: run("llama_accum16", "llama3-405b", "train_4k",
                                 multi_pod=True, grad_accum=16),
    "llama_accum4": lambda: run("llama_accum4", "llama3-405b", "train_4k",
                                multi_pod=True, grad_accum=4),
    # cell B: most collective-bound train cell — TP vs pure-FSDP sharding
    "stablelm_base": lambda: run("stablelm_base", "stablelm-1.6b",
                                 "train_4k"),
    "stablelm_fsdp": lambda: run("stablelm_fsdp", "stablelm-1.6b",
                                 "train_4k", sharding_mode="fsdp"),
    "qwen3_train_base": lambda: run("qwen3_train_base", "qwen3-14b",
                                    "train_4k"),
    "qwen3_train_fsdp": lambda: run("qwen3_train_fsdp", "qwen3-14b",
                                    "train_4k", sharding_mode="fsdp"),
    # cell C: the paper's technique on serving — bf16 vs MPAI int8 deploy
    "qwen3_decode_bf16": lambda: run("qwen3_decode_bf16", "qwen3-14b",
                                     "decode_32k"),
    "qwen3_decode_mpai": lambda: run("qwen3_decode_mpai", "qwen3-14b",
                                     "decode_32k", plan="mpai"),
    "qwen3_prefill_bf16": lambda: run("qwen3_prefill_bf16", "qwen3-14b",
                                      "prefill_32k"),
    "qwen3_prefill_mpai": lambda: run("qwen3_prefill_mpai", "qwen3-14b",
                                      "prefill_32k", plan="mpai"),
}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or not args.names:
        print("\n".join(EXPERIMENTS))
        sys.exit(0)
    for name in args.names:
        EXPERIMENTS[name]()
