#!/usr/bin/env python
"""benchstat — append bench results to a history ledger and gate drift.

    python tools/benchstat.py --history BENCH_history.jsonl BENCH_*.json
    python tools/benchstat.py --history BENCH_history.jsonl --check BENCH_*.json

Each invocation flattens every numeric leaf of the given ``BENCH_*.json``
artifacts into dotted metric keys (``obs_overhead.overhead``,
``decode.tiny-mha.tokens_per_s`` ...) and appends **one** JSONL record —
wall timestamp, git sha, metrics — to the history ledger.  CI restores
the ledger across runs (actions/cache or artifact download), so it
accumulates the perf trajectory the single-run ``--check`` gates inside
each bench cannot see: a 1%/week slow drift passes every per-run gate
and still loses 15% in a quarter.

``--check`` compares the *current* run against the trailing median of
the last ``--window`` ledger records (median, not mean: one noisy CI box
must not poison the baseline), for the directional keys only:

  * keys containing ``tokens_per`` or ``speedup`` are higher-is-better —
    fail when current < median x (1 - --rel-slack);
  * keys containing ``overhead`` are lower-is-better — fail when
    current > median + --abs-slack (overheads are small fractions; a
    relative gate on ~0.01 values would be pure noise);
  * everything else (counts, timestamps, config echoes) is recorded but
    never gated, and any key with a ``max_*``/``min_*`` path segment is
    skipped outright (those echo gate *configuration*, not measurement).

With fewer than ``--min-history`` prior records the check passes
trivially (the ledger is still warming up).  The record is appended
whether or not the gate fails, so the ledger never has survivorship
bias.  This file is a host-side tool: wall clocks and subprocesses are
fine here (``src/repro`` — fleetlint-linted — is where they are banned).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import statistics
import subprocess
import sys


def flatten(obj, prefix="") -> dict:
    """Numeric leaves of a nested JSON value as {dotted_key: float}."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            # a list of scenario dicts indexes by scenario name, so the
            # key survives reordering; anything else indexes by position
            tag = (v.get("scenario", str(i))
                   if isinstance(v, dict) else str(i))
            out.update(flatten(v, f"{prefix}{tag}."))
    elif isinstance(obj, bool):
        pass                              # True/False are flags, not metrics
    elif isinstance(obj, (int, float)):
        out[prefix[:-1]] = float(obj)
    return out


def gate_direction(key: str) -> str | None:
    """'up' (higher-better), 'down' (lower-better), or None (ungated)."""
    segments = key.split(".")
    if any(s.startswith(("max_", "min_")) for s in segments):
        return None                       # config echo, not a measurement
    last = segments[-1]
    if "tokens_per" in last or "speedup" in last or "ratio" in last:
        return "up"
    if "overhead" in last:
        return "down"
    return None


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            stderr=subprocess.DEVNULL).decode().strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def load_history(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def check(metrics: dict, history: list[dict], window: int,
          min_history: int, rel_slack: float, abs_slack: float) -> list[str]:
    """Regression messages for the current metrics vs trailing medians."""
    if len(history) < min_history:
        print(f"benchstat: {len(history)} prior record(s) < "
              f"--min-history {min_history}; check passes trivially")
        return []
    tail = history[-window:]
    failures = []
    for key, value in sorted(metrics.items()):
        direction = gate_direction(key)
        if direction is None:
            continue
        prior = [r["metrics"][key] for r in tail if key in r["metrics"]]
        if len(prior) < min_history:
            continue                      # new metric: let it warm up
        med = statistics.median(prior)
        if direction == "up" and value < med * (1.0 - rel_slack):
            failures.append(
                f"{key}: {value:g} fell below trailing median {med:g} "
                f"by more than {rel_slack:.0%} ({len(prior)} samples)")
        elif direction == "down" and value > med + abs_slack:
            failures.append(
                f"{key}: {value:g} rose above trailing median {med:g} "
                f"by more than {abs_slack:g} ({len(prior)} samples)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_files", nargs="+", metavar="BENCH.json")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="JSONL ledger to append to (default "
                         "BENCH_history.jsonl)")
    ap.add_argument("--check", action="store_true",
                    help="fail on drift vs the trailing median")
    ap.add_argument("--window", type=int, default=20,
                    help="trailing records the median is taken over")
    ap.add_argument("--min-history", type=int, default=3,
                    help="prior records required before gating")
    ap.add_argument("--rel-slack", type=float, default=0.15,
                    help="allowed fractional drop for higher-is-better "
                         "keys (default 0.15 — CI boxes are noisy)")
    ap.add_argument("--abs-slack", type=float, default=0.01,
                    help="allowed absolute rise for overhead keys")
    args = ap.parse_args(argv)

    metrics = {}
    for path in args.bench_files:
        stem = os.path.splitext(os.path.basename(path))[0]
        stem = stem[len("BENCH_"):] if stem.startswith("BENCH_") else stem
        with open(path) as f:
            metrics.update(flatten(json.load(f), f"{stem}."))
    history = load_history(args.history)

    failures = (check(metrics, history, args.window, args.min_history,
                      args.rel_slack, args.abs_slack)
                if args.check else [])

    # append before exiting either way: the ledger must record failing
    # runs too, or the baseline only ever sees survivors
    record = {
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "sha": git_sha(),
        "files": [os.path.basename(p) for p in args.bench_files],
        "metrics": metrics,
    }
    with open(args.history, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    gated = sum(1 for k in metrics if gate_direction(k) is not None)
    print(f"benchstat: recorded {len(metrics)} metrics ({gated} gated) "
          f"from {len(args.bench_files)} file(s); "
          f"history now {len(history) + 1} record(s)")

    if failures:
        print(f"benchstat: {len(failures)} regression(s) vs the trailing "
              f"median of {min(len(history), args.window)} record(s):",
            file=sys.stderr)
        for msg in failures:
            print(f"  REGRESSION {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
