#!/usr/bin/env python
"""fleetlint launcher: the repo-root entry point for the analyzer.

Same engine as ``python -m repro.analysis`` (it bootstraps
``PYTHONPATH`` itself so it runs from a bare checkout), plus the fast
local-iteration mode:

    tools/fleetlint.py                  # full tree vs baseline
    tools/fleetlint.py --diff           # only files changed vs main
    tools/fleetlint.py --diff origin/x  # ... vs another ref
    tools/fleetlint.py --json --output LINT_report.json

``--diff`` lints only the changed ``src/repro/*.py`` files (plus any
project pass whose subject files changed), so a kernel edit doesn't
re-lint the router.  Stale-suppression detection is skipped on a diff
slice — only the full run can prove an entry dead.  Exit codes match
the module CLI: 0 clean, 1 findings, 2 usage/baseline error.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis.__main__ import main as _main          # noqa: E402


def _changed_files(ref: str) -> list:
    """src/repro python files changed vs ``ref`` (plus uncommitted)."""
    out = set()
    for args in (["git", "diff", "--name-only", ref, "--"],
                 ["git", "diff", "--name-only", "--"],
                 ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            res = subprocess.run(args, cwd=REPO_ROOT, capture_output=True,
                                 text=True, check=True)
        except (subprocess.CalledProcessError, FileNotFoundError) as e:
            print(f"fleetlint: {' '.join(args)} failed: {e}",
                  file=sys.stderr)
            sys.exit(2)
        out.update(line.strip() for line in res.stdout.splitlines()
                   if line.strip())
    return sorted(p for p in out
                  if p.endswith(".py") and p.startswith("src/repro/")
                  and os.path.exists(os.path.join(REPO_ROOT, p)))


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--diff" in argv:
        i = argv.index("--diff")
        argv.pop(i)
        ref = "main"
        if i < len(argv) and not argv[i].startswith("-"):
            ref = argv.pop(i)
        changed = _changed_files(ref)
        if not changed:
            print(f"fleetlint: no src/repro changes vs {ref}")
            return 0
        print(f"fleetlint: linting {len(changed)} changed file(s) vs "
              f"{ref}", file=sys.stderr)
        argv = changed + argv
    return _main(argv)


if __name__ == "__main__":
    sys.exit(main())
