"""Sharded checkpoint manager: async save, atomic commit, keep-last-K,
elastic restore.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/ -> renamed to step_000123/ on commit
        arrays.npz        flat {path: np.ndarray} of params + opt state
        manifest.json     step, tree structure hash, param count

Saves run on a background thread (training never blocks on storage);
commit is the atomic directory rename, so a crash mid-save leaves only a
.tmp that restore ignores.  Restore rebuilds the pytree and device_puts
with *whatever shardings the new mesh provides* — the elastic-restart
path (mesh shape may differ from the saving run's).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()                                   # one in flight at a time
        host_tree = jax.tree_util.tree_map(np.asarray, tree)   # D2H now

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
                final = os.path.join(self.dir, f"step_{step:08d}")
                os.makedirs(tmp, exist_ok=True)
                flat = _flatten(host_tree)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                manifest = {"step": step, "num_arrays": len(flat),
                            "time": time.time()}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)                 # atomic commit
                self._gc()
            except BaseException as e:                # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Rebuild ``tree_like``'s structure from disk.  ``shardings``
        (optional pytree of NamedSharding) places leaves on the current
        mesh — the elastic-restart entry point."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for p, like in paths:
            key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                           for e in p)
            arr = flat[key]
            assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
            leaves.append(arr.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
