"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective_bytes from parsing the (partitioned, pre-SPMD) HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op, summing *operand* sizes (resolved through a def-map of named values).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) measures how much of the
compiled compute is useful (remat/redundancy waste shows up as a low
ratio).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# TPU v5e constants (per chip)
PEAK_BF16 = 197e12          # FLOP/s
PEAK_INT8 = 394e12          # OP/s
HBM_BW = 819e9              # B/s
LINK_BW = 50e9              # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Bytes of one HLO type string (sums tuple elements)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in an HLO module text."""
    defs: Dict[str, float] = {}
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1).lstrip("%"), m.group(2)
        # record this value's result bytes (type prefix of rhs)
        defs[name] = _shape_bytes(rhs.split(" ", 1)[0]) or _shape_bytes(
            rhs[:rhs.find(")") + 1] if "(" in rhs else rhs)
        kind = next((c for c in _COLLECTIVES
                     if re.search(rf"\b{c}(?:-start|-done)?\(", rhs)), None)
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue                      # avoid double count of async pairs
        # operand names inside the call parens (up to the matching close)
        lo = rhs.find("(")
        hi = rhs.find(")", lo)
        call = rhs[lo:hi + 1]
        ops = re.findall(r"%?([\w.\-]+)(?:,|\))", call)
        op_bytes = sum(defs.get(o, 0.0) for o in ops)
        if op_bytes == 0.0:               # fallback: result size
            op_bytes = _shape_bytes(rhs.split(" ", 1)[0])
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + op_bytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineReport:
    """Per-device quantities: ``compiled.cost_analysis()`` and the
    optimized-HLO collective parse are both per-partition in an SPMD
    module, so globals are (per-device x chips) and the spec's
    global/(chips*peak) formulas reduce to per_device/peak."""
    arch: str
    shape: str
    mesh: Tuple[int, ...]
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # per device
    model_flops: float          # global (6*N_active*D)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = (self.hlo_flops * self.chips) / (self.chips * PEAK_BF16)
        self.memory_s = (self.hlo_bytes * self.chips) / (self.chips * HBM_BW)
        self.collective_s = (self.collective_bytes * self.chips) / (
            self.chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU bound implied by the dominant term."""
        if self.step_s == 0:
            return 0.0
        return self.model_flops / (self.step_s * self.chips * PEAK_BF16)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape,
            "mesh": "x".join(map(str, self.mesh)), "chips": self.chips,
            "hlo_gflops": round(self.hlo_flops / 1e9, 2),
            "hlo_gbytes": round(self.hlo_bytes / 1e9, 3),
            "coll_gbytes": round(self.collective_bytes / 1e9, 3),
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "model_gflops": round(self.model_flops / 1e9, 2),
            "useful_ratio": round(self.useful_flops_ratio, 3),
            "roofline_frac": round(self.roofline_fraction, 4),
        }


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training; 2*N_active*tokens for single forward."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
