"""AdamW with cosine schedule, global-norm clipping and microbatch
gradient accumulation — the training substrate.

Optimizer state shards exactly like the parameters (ZeRO-1 comes for free
for fsdp archs, whose params already shard over (data, model)).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=dtype)
    return AdamWState(jax.tree_util.tree_map(zeros, params),
                      jax.tree_util.tree_map(zeros, params),
                      jnp.zeros((), jnp.int32))


def cosine_lr(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    return tc.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def apply_updates(params, grads, state: AdamWState,
                  tc: TrainConfig) -> Tuple[Any, AdamWState, jnp.ndarray]:
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    count = state.count + 1
    lr = cosine_lr(tc, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        st_dtype = m.dtype                       # optimizer-state dtype
        mf = tc.b1 * m.astype(jnp.float32) + (1 - tc.b1) * g
        vf = tc.b2 * v.astype(jnp.float32) + (1 - tc.b2) * jnp.square(g)
        mhat = mf / (1 - tc.b1 ** count)
        vhat = vf / (1 - tc.b2 ** count)
        step = mhat / (jnp.sqrt(vhat) + 1e-8)
        decay = tc.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return ((p - lr * (step + decay)).astype(p.dtype),
                mf.astype(st_dtype), vf.astype(st_dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_mu, new_nu, count), gnorm
