"""Int8 gradient compression for the cross-pod DP hop.

The inter-pod links are the scarcest bandwidth in the production mesh
(DCN/ICI trunk vs intra-pod ICI).  This implements the standard
chunk-scaled int8 all-reduce: each pod quantizes its gradient shard with a
per-chunk fp32 scale, all-gathers the (int8, scale) pairs over the pod
axis, and averages the dequantized copies — 4x fewer bytes on the trunk
than an fp32 ring all-reduce, ~2x vs bf16.  Reuses the MPAI quantization
machinery (symmetric absmax), applied to a different tensor class.

Error feedback (residual carrying) keeps the bias bounded across steps.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048
QMAX = 127.0


def _chunk_quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    c = flat.reshape(-1, CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(c), axis=1, keepdims=True), 1e-12) / QMAX
    q = jnp.clip(jnp.round(c / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def _chunk_dequant(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_pmean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean over ``axis_name`` moving int8+scales instead of fp32.

    Must run inside shard_map/pmap with ``axis_name`` bound.
    """
    q, scale = _chunk_quant(x)
    qs = jax.lax.all_gather(q, axis_name)           # [P, nchunk, CHUNK] int8
    ss = jax.lax.all_gather(scale, axis_name)       # [P, nchunk, 1] f32
    deq = qs.astype(jnp.float32) * ss
    mean = jnp.mean(deq, axis=0)
    return _reshape(mean, x.shape)


def _reshape(c: jnp.ndarray, shape) -> jnp.ndarray:
    flat = c.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_grad_mean(grads: Any, axis_name: str) -> Any:
    """Tree-wide compressed mean (large leaves only; small ones go fp32)."""
    def one(g):
        if g.size >= CHUNK:
            return compressed_pmean(g, axis_name)
        return jax.lax.pmean(g, axis_name)
    return jax.tree_util.tree_map(one, grads)


class ErrorFeedback:
    """Residual accumulator: feed ``apply`` the raw grad, get the
    compressed-communicated grad plus carried quantization residual."""

    @staticmethod
    def init(grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def apply(grads, residual, axis_name: str):
        def one(g, r):
            gf = g.astype(jnp.float32) + r
            if g.size >= CHUNK:
                q, scale = _chunk_quant(gf)
                local_deq = _reshape((q.astype(jnp.float32) * scale), gf.shape)
                new_r = gf - local_deq
                mean = compressed_pmean(gf, axis_name)
                return mean.astype(g.dtype), new_r
            return jax.lax.pmean(gf, axis_name).astype(g.dtype), jnp.zeros_like(gf)
        pairs = jax.tree_util.tree_map(one, grads, residual)
        out = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return out, res
