"""Open-loop traffic generation against a ServingClient.

The Poisson driver every surface shares — ``launch/route.py``,
``benchmarks/router_bench.py``, examples, and tests all used to carry
their own copy of this loop; it now lives here once.  Arrival times,
class draws, and payload draws consume the seeded RNG in the same order
as the original ``launch/route.py`` implementation, so seeded runs
reproduce the pre-facade traces exactly.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.client import ResponseHandle, ServingClient


def poisson_arrivals(classes: Sequence, weights: Sequence[float],
                     rate_hz: float, n_requests: int, seed: int = 0,
                     payload_fn: Optional[Callable] = None
                     ) -> List[Tuple[float, object, object]]:
    """Draw an open-loop arrival trace: [(arrival_s, slo, payload)]."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_hz)
        slo = classes[rng.choice(len(classes), p=weights)]
        out.append((t, slo, payload_fn(rng) if payload_fn else None))
    return out


def open_loop(client: ServingClient, classes: Sequence,
              weights: Sequence[float], rate_hz: float, n_requests: int,
              seed: int = 0, dt: Optional[float] = None,
              payload_fn: Optional[Callable] = None,
              max_s: float = 600.0) -> List[ResponseHandle]:
    """Drive Poisson open-loop traffic through the fleet until drained.

    ``payload_fn(rng)`` (optional) draws each request's payload: a token
    prompt array or a prebuilt :class:`~repro.serving.executor.LMWork`
    for LM pools; None routes cost-model requests.  Returns every
    request's handle (rejected submissions included — check
    ``handle.admitted``).
    """
    arrivals = poisson_arrivals(classes, weights, rate_hz, n_requests,
                                seed=seed, payload_fn=payload_fn)
    handles: List[ResponseHandle] = []
    i = 0
    while i < len(arrivals) or client.outstanding or client.pending_faults:
        client.advance(dt)
        while i < len(arrivals) and arrivals[i][0] <= client.now:
            at, slo, payload = arrivals[i]
            handles.append(client.submit(payload, slo=slo, arrival=at))
            i += 1
        client.pump()
        if client.now > max_s:          # safety net: never loop forever
            break
    return handles
