"""Open-loop traffic generation against a ServingClient.

The Poisson driver every surface shares — ``launch/route.py``,
``benchmarks/router_bench.py``, examples, and tests all used to carry
their own copy of this loop; it now lives here once.  Arrival times,
class draws, and payload draws consume the seeded RNG in the same order
as the original ``launch/route.py`` implementation, so seeded runs
reproduce the pre-facade traces exactly.
"""
from __future__ import annotations

import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.client import ResponseHandle, ServingClient


class TrafficTrace(List[ResponseHandle]):
    """``open_loop``'s return value: a plain list of handles (fully
    backward compatible) plus truncation markers.  A run that hits the
    ``max_s`` safety net used to return normally with arrivals never
    submitted and handles incomplete — benchmark traces silently
    shrank; now ``truncated`` flags it (and a warning fires), and
    ``unsubmitted``/``incomplete`` say what was lost, so ``--check``
    gates can fail loudly instead of gating on a partial trace."""
    truncated: bool = False
    unsubmitted: int = 0                 # arrivals never submitted
    incomplete: int = 0                  # submitted but unfinished handles


def poisson_arrivals(classes: Sequence, weights: Sequence[float],
                     rate_hz: float, n_requests: int, seed: int = 0,
                     payload_fn: Optional[Callable] = None
                     ) -> List[Tuple[float, object, object]]:
    """Draw an open-loop arrival trace: [(arrival_s, slo, payload)]."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_hz)
        slo = classes[rng.choice(len(classes), p=weights)]
        out.append((t, slo, payload_fn(rng) if payload_fn else None))
    return out


def open_loop(client: ServingClient, classes: Sequence,
              weights: Sequence[float], rate_hz: float, n_requests: int,
              seed: int = 0, dt: Optional[float] = None,
              payload_fn: Optional[Callable] = None,
              max_s: float = 600.0) -> TrafficTrace:
    """Drive Poisson open-loop traffic through the fleet until drained.

    ``payload_fn(rng)`` (optional) draws each request's payload: a token
    prompt array or a prebuilt :class:`~repro.serving.executor.LMWork`
    for LM pools; None routes cost-model requests.  Returns every
    request's handle (rejected submissions included — check
    ``handle.admitted``) as a :class:`TrafficTrace`; a run cut short by
    the ``max_s`` safety net is marked ``truncated`` and warns, so
    benchmark gates never silently score a shrunken trace.
    """
    arrivals = poisson_arrivals(classes, weights, rate_hz, n_requests,
                                seed=seed, payload_fn=payload_fn)
    handles = TrafficTrace()
    i = 0
    while i < len(arrivals) or client.outstanding or client.pending_faults:
        client.advance(dt)
        while i < len(arrivals) and arrivals[i][0] <= client.now:
            at, slo, payload = arrivals[i]
            handles.append(client.submit(payload, slo=slo, arrival=at))
            i += 1
        client.pump()
        if client.now > max_s:          # safety net: never loop forever
            handles.truncated = True
            handles.unsubmitted = len(arrivals) - i
            handles.incomplete = sum(1 for h in handles if not h.done)
            warnings.warn(
                f"open_loop truncated at the max_s={max_s}s safety net: "
                f"{handles.unsubmitted} arrivals never submitted, "
                f"{handles.incomplete} in-flight requests incomplete — "
                f"metrics over this trace undercount the offered load",
                RuntimeWarning, stacklevel=2)
            break
    return handles
