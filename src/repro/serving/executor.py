"""Engine-backed pool executor: adapts an LM server to the router.

:class:`EngineExecutor` is the bridge between the routing fabric
(``router/pool.py`` calls ``executor.run(plan, batch)`` and gets back
``(latency_s, energy_j)``) and a real decode server — the
:class:`~repro.runtime.serve.ContinuousBatchingEngine` by preference,
or the windowed baseline for comparison runs.  Beyond the old
``ServerExecutor`` it:

* understands :class:`LMWork` payloads (per-request ``max_new`` and
  :class:`~repro.runtime.sampling.SamplingParams`), not just raw prompt
  arrays;
* relays the engine's per-token callback upward (rid, token, engine
  decode step) — the feed for ``ResponseHandle.stream()``;
* records *decode-only* telemetry into the pool's counters
  (``decode_tokens`` / ``decode_s`` deltas around each batch), so
  ``tokens/s`` in snapshots is decode throughput, not
  prefill-window-idle-time-diluted throughput — consistent with
  ``benchmarks/decode_bench.py``;
* surfaces the engine's ``OutOfBlocksError`` admission deferrals as a
  backpressure counter (``deferrals``) in the same snapshot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import ScheduledPlan
from repro.router.pool import RouterRequest
from repro.router.telemetry import PoolCounters
from repro.runtime.sampling import SamplingParams


@dataclass
class LMWork:
    """One LM request flowing through the facade: prompt in, tokens out."""
    prompt: np.ndarray
    max_new: Optional[int] = None        # None -> the pool's default
    sampling: Optional[SamplingParams] = None
    output: Optional[np.ndarray] = None
    # disaggregated pools stamp which stage pool prefilled the prompt
    # (None on unified pools; the routed pool itself decodes either way)
    prefill_pool: Optional[str] = None


class EngineExecutor:
    """Execute a routed batch on a real LM server (continuous-batching
    engine or the windowed baseline — same submit/step/done API).

    Request payloads are :class:`LMWork` (or bare token prompts); the
    batch is submitted and driven to completion with the server's
    non-blocking ``step()``.  Latency is measured wall time; energy is
    the plan's nominal per-inference estimate scaled by the tokens the
    batch actually decoded (decode-only, matching decode_tokens_per_s).
    Given ``counters`` (the pool's PoolCounters — the same object
    Telemetry reads) it records decode telemetry: tokens generated,
    slot occupancy after every step, and decode-only token/time deltas.
    """

    def __init__(self, server, max_new: int = 8,
                 counters: Optional[PoolCounters] = None,
                 on_token: Optional[Callable[[int, int, int], None]] = None,
                 prefill_pool: Optional[str] = None,
                 prefill_counters: Optional[PoolCounters] = None,
                 prefill_energy_scale: float = 0.5):
        self.server = server
        self.max_new = max_new
        self.counters = counters
        self.on_token = on_token             # (rid, token, engine_step)
        # disaggregated (CoProcServer) pools: the prefill stage's own
        # telemetry identity — its counters are charged here (tokens
        # prefilled, stage wall time, energy at the DPU-analogue's
        # discounted per-token rate) and registered with the router so
        # the fleet snapshot and orbit energy bucket see both stages
        self.prefill_pool = prefill_pool
        self.prefill_counters = prefill_counters
        self.prefill_energy_scale = prefill_energy_scale
        # flight recorder: the Router wires the fleet's shared tracer
        # and this pool's name in; engine stage events (admit, prefill
        # chunks, handoff, import, decode steps) are relayed into it
        # while a traced batch runs
        self.tracer = None
        self.pool_name = None
        if hasattr(server, "on_token"):
            server.on_token = self._relay

    def _relay(self, rid: int, tok: int) -> None:
        if self.on_token is not None:
            self.on_token(rid, tok, getattr(self.server, "decode_steps", 0))

    def _stats(self) -> Tuple[int, float, int, int, float]:
        s = self.server
        return (getattr(s, "decode_tokens", 0),
                getattr(s, "decode_s", 0.0),
                getattr(s, "deferrals", 0),
                getattr(s, "prefill_tokens", 0),
                getattr(s, "admit_s", 0.0))

    def _hstats(self) -> Tuple[int, int, int, int]:
        """Hardening counters (zero on servers without the layer)."""
        s = self.server
        return (getattr(s, "bitflips_detected", 0),
                getattr(s, "blocks_quarantined", 0),
                getattr(s, "watchdog_trips", 0),
                getattr(s, "handoffs_replayed", 0))

    def _sstats(self) -> Tuple[int, int, Dict[str, int]]:
        """Seam/sharing counters: prefix-index traffic and per-shard
        handoff imports (empty on unsharded/paged-less servers)."""
        s = self.server
        return (getattr(s, "prefix_hits", 0),
                getattr(s, "prefix_lookups", 0),
                dict(getattr(s, "imports_by_shard", {}) or {}))

    def _install_stage_relay(self, plan: ScheduledPlan, now: float,
                             wall0: float) -> bool:
        """While this batch runs, forward the engine's ``on_stage``
        events into the tracer, anchoring wall perf_counter times at the
        batch's *virtual* launch instant ``now`` — engine sub-spans
        (chunks, decode steps) then nest inside the pool's virtual
        ``serve`` span.  Per-request stages (prefill chunks, the
        handoff, the import) record under their rid; batch-wide stages
        (fused admit, decode steps) are pool-lane spans (rid=None).
        Disaggregated pools put prefill-side stages on the prefill stage
        pool's lane and stamp each chunk's energy at the discounted
        per-token rate, so summed chunk energy equals the stage
        counters' charge exactly."""
        tr = self.tracer
        if (tr is None or not tr.enabled
                or not hasattr(self.server, "on_stage")):
            return False
        decode_pool = self.pool_name
        pre_pool = self.prefill_pool or decode_pool
        chunk_e = (plan.energy_j * self.prefill_energy_scale
                   if self.prefill_counters is not None else None)

        def relay(stage, w0, w1, rids, attrs):
            vt0, vt1 = now + (w0 - wall0), now + (w1 - wall0)
            if stage in ("admit", "decode_step",
                         "seu_bitflip", "bitflip_detected"):
                # batch-wide (or block-level, rid-less) events live on
                # the pool lane
                tr.add(None, stage, vt0, vt1, pool=decode_pool,
                       rids=len(rids), **attrs)
                return
            pool = pre_pool if stage in ("prefill_chunk",
                                         "handoff") else decode_pool
            extra = ({} if chunk_e is None or stage != "prefill_chunk"
                     else {"energy_j": chunk_e * attrs.get("tokens", 0)})
            for rid in rids:
                tr.add(rid, stage, vt0, vt1, pool=pool, **attrs, **extra)

        self.server.on_stage = relay
        return True

    def run(self, plan: ScheduledPlan, requests: Sequence[RouterRequest],
            now: float = 0.0) -> Tuple[float, float]:
        from repro.runtime.serve import Request as ServeRequest
        t0 = time.perf_counter()
        traced = self._install_stage_relay(plan, now, t0)
        tok0, dec0, def0, pre0, adm0 = self._stats()
        h0 = self._hstats()
        px0, pl0, sh0 = self._sstats()
        want = {}
        for r in requests:
            work = (r.payload if isinstance(r.payload, LMWork)
                    else LMWork(np.asarray(r.payload, np.int32)))
            r.payload = work
            if r.rid in self.server.done:
                # failover re-dispatch of a batch this server already
                # ran to completion: hand back the finished output
                # instead of decoding (and emitting tokens) twice
                work.output = self.server.done[r.rid].output
                continue
            max_new = self.max_new if work.max_new is None else work.max_new
            pad_fn = getattr(self.server, "padded_prompt_len", None)
            if pad_fn is None:             # windowed baseline: hard bucket
                if work.prompt.shape[0] > self.server.prompt_len:
                    raise ValueError(
                        f"request {r.rid}: prompt of "
                        f"{work.prompt.shape[0]} tokens exceeds this "
                        f"windowed pool's prompt_len bucket of "
                        f"{self.server.prompt_len}; route it to an "
                        f"engine pool (chunked paged prefill lifts the "
                        f"bucket limit)")
                padded = self.server.prompt_len
            else:
                padded = pad_fn(int(work.prompt.shape[0]))
            if padded + max_new > self.server.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({work.prompt.shape[0]} "
                    f"tokens, {padded} padded) + max_new={max_new} "
                    f"exceeds this pool's max_len={self.server.max_len} "
                    f"(PoolSpec max_prompt_len/max_new size the KV "
                    f"allocation; raise them or shrink the request)")
            work.prefill_pool = self.prefill_pool
            want[r.rid] = work
            self.server.submit(ServeRequest(r.rid, work.prompt,
                                            max_new=max_new,
                                            sampling=work.sampling))
        while not all(rid in self.server.done for rid in want):
            self.server.step()
            if self.counters is not None and hasattr(self.server,
                                                     "occupancy"):
                self.counters.slot_occupancy.record(self.server.occupancy)
        for rid, work in want.items():
            work.output = self.server.done[rid].output
        tok1, dec1, def1, pre1, adm1 = self._stats()
        h1 = self._hstats()
        if self.counters is not None:
            self.counters.tokens_generated += sum(
                int(w.output.shape[0]) for w in want.values())
            self.counters.decode_tokens += tok1 - tok0
            self.counters.decode_s += dec1 - dec0
            self.counters.deferrals += def1 - def0
            self.counters.bitflips_detected += h1[0] - h0[0]
            self.counters.blocks_quarantined += h1[1] - h0[1]
            self.counters.watchdog_trips += h1[2] - h0[2]
            self.counters.handoffs_replayed += h1[3] - h0[3]
            px1, pl1, sh1 = self._sstats()
            self.counters.prefix_hits += px1 - px0
            self.counters.prefix_lookups += pl1 - pl0
            for shard, n in sh1.items():
                delta = n - sh0.get(shard, 0)
                if delta:
                    self.counters.imports_by_shard[shard] = (
                        self.counters.imports_by_shard.get(shard, 0)
                        + delta)
            if self.prefill_counters is None:
                self.counters.prefill_tokens += pre1 - pre0
        if self.prefill_counters is not None:
            # disaggregated pool: the prefill stage's share of this
            # batch — prompt tokens pushed through the DPU-analogue
            # engine, its wall time, and its energy at the discounted
            # per-token rate — lands on ITS pool counters, never the
            # decode pool's, so snapshots and the orbit bucket attribute
            # each stage's joules to the hardware that spent them
            pc = self.prefill_counters
            pc.dispatched += len(want)
            pc.completed += len(want)
            pc.prefill_tokens += pre1 - pre0
            pc.busy_s += adm1 - adm0
            pc.energy_j += (plan.energy_j * self.prefill_energy_scale
                            * (pre1 - pre0))
        # Energy scales with tokens actually decoded this batch (every
        # decode step is one forward pass priced at the plan's nominal
        # per-inference energy_j) — not with request count, which would
        # charge a max_new=16 request the same as a max_new=1 one and
        # charge failover re-serves (zero decode) all over again.  The
        # decode-only basis matches the pool's decode_tokens_per_s
        # telemetry, so the orbit energy bucket drains against real work.
        if traced:
            self.server.on_stage = None
        return time.perf_counter() - t0, plan.energy_j * (tok1 - tok0)
