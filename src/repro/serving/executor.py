"""Engine-backed pool executor: adapts an LM server to the router.

:class:`EngineExecutor` is the bridge between the routing fabric
(``router/pool.py`` calls ``executor.run(plan, batch)`` and gets back
``(latency_s, energy_j)``) and a real decode server — the
:class:`~repro.runtime.serve.ContinuousBatchingEngine` by preference,
or the windowed baseline for comparison runs.  Beyond the old
``ServerExecutor`` it:

* understands :class:`LMWork` payloads (per-request ``max_new`` and
  :class:`~repro.runtime.sampling.SamplingParams`), not just raw prompt
  arrays;
* relays the engine's per-token callback upward (rid, token, engine
  decode step) — the feed for ``ResponseHandle.stream()``;
* records *decode-only* telemetry into the pool's counters
  (``decode_tokens`` / ``decode_s`` deltas around each batch), so
  ``tokens/s`` in snapshots is decode throughput, not
  prefill-window-idle-time-diluted throughput — consistent with
  ``benchmarks/decode_bench.py``;
* surfaces the engine's ``OutOfBlocksError`` admission deferrals as a
  backpressure counter (``deferrals``) in the same snapshot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import ScheduledPlan
from repro.router.pool import RouterRequest
from repro.router.telemetry import PoolCounters
from repro.runtime.sampling import SamplingParams


@dataclass
class LMWork:
    """One LM request flowing through the facade: prompt in, tokens out."""
    prompt: np.ndarray
    max_new: Optional[int] = None        # None -> the pool's default
    sampling: Optional[SamplingParams] = None
    output: Optional[np.ndarray] = None


class EngineExecutor:
    """Execute a routed batch on a real LM server (continuous-batching
    engine or the windowed baseline — same submit/step/done API).

    Request payloads are :class:`LMWork` (or bare token prompts); the
    batch is submitted and driven to completion with the server's
    non-blocking ``step()``.  Latency is measured wall time; energy is
    the plan's nominal per-inference estimate scaled by the tokens the
    batch actually decoded (decode-only, matching decode_tokens_per_s).
    Given ``counters`` (the pool's PoolCounters — the same object
    Telemetry reads) it records decode telemetry: tokens generated,
    slot occupancy after every step, and decode-only token/time deltas.
    """

    def __init__(self, server, max_new: int = 8,
                 counters: Optional[PoolCounters] = None,
                 on_token: Optional[Callable[[int, int, int], None]] = None):
        self.server = server
        self.max_new = max_new
        self.counters = counters
        self.on_token = on_token             # (rid, token, engine_step)
        if hasattr(server, "on_token"):
            server.on_token = self._relay

    def _relay(self, rid: int, tok: int) -> None:
        if self.on_token is not None:
            self.on_token(rid, tok, getattr(self.server, "decode_steps", 0))

    def _stats(self) -> Tuple[int, float, int]:
        s = self.server
        return (getattr(s, "decode_tokens", 0),
                getattr(s, "decode_s", 0.0),
                getattr(s, "deferrals", 0))

    @property
    def max_new_budget(self) -> int:
        """Largest per-request ``max_new`` this server can honor."""
        return self.server.max_len - self.server.prompt_len

    def run(self, plan: ScheduledPlan,
            requests: Sequence[RouterRequest]) -> Tuple[float, float]:
        from repro.runtime.serve import Request as ServeRequest
        t0 = time.perf_counter()
        tok0, dec0, def0 = self._stats()
        want = {}
        for r in requests:
            work = (r.payload if isinstance(r.payload, LMWork)
                    else LMWork(np.asarray(r.payload, np.int32)))
            r.payload = work
            if r.rid in self.server.done:
                # failover re-dispatch of a batch this server already
                # ran to completion: hand back the finished output
                # instead of decoding (and emitting tokens) twice
                work.output = self.server.done[r.rid].output
                continue
            max_new = self.max_new if work.max_new is None else work.max_new
            if max_new > self.max_new_budget:
                raise ValueError(
                    f"request {r.rid}: max_new={max_new} exceeds this "
                    f"pool's budget of {self.max_new_budget} (PoolSpec "
                    f"max_new sizes the KV allocation; raise it or "
                    f"lower the request's max_new)")
            want[r.rid] = work
            self.server.submit(ServeRequest(r.rid, work.prompt,
                                            max_new=max_new,
                                            sampling=work.sampling))
        while not all(rid in self.server.done for rid in want):
            self.server.step()
            if self.counters is not None and hasattr(self.server,
                                                     "occupancy"):
                self.counters.slot_occupancy.record(self.server.occupancy)
        for rid, work in want.items():
            work.output = self.server.done[rid].output
        tok1, dec1, def1 = self._stats()
        if self.counters is not None:
            self.counters.tokens_generated += sum(
                int(w.output.shape[0]) for w in want.values())
            self.counters.decode_tokens += tok1 - tok0
            self.counters.decode_s += dec1 - dec0
            self.counters.deferrals += def1 - def0
        # Energy scales with tokens actually decoded this batch (every
        # decode step is one forward pass priced at the plan's nominal
        # per-inference energy_j) — not with request count, which would
        # charge a max_new=16 request the same as a max_new=1 one and
        # charge failover re-serves (zero decode) all over again.  The
        # decode-only basis matches the pool's decode_tokens_per_s
        # telemetry, so the orbit energy bucket drains against real work.
        return time.perf_counter() - t0, plan.energy_j * (tok1 - tok0)
