"""Stage-axis decode pool: one LM server spanning a device group.

:class:`StageAxisEngine` backs a serving pool with the GPipe-style
stage pipeline from :mod:`repro.core.pipeline` — segment 0 of the
partition plan (the int8 backbone, MPAI's DPU analogue) lives on device
group 0, the high-precision tail + head on group 1, and activations
hand off over ``lax.ppermute`` while the first group starts the next
microbatch.  Each *slot* is one microbatch, so a full step keeps every
stage busy: slot i+1's backbone overlaps slot i's tail.

Decode is full-sequence recompute: every step re-runs each active
slot's whole token prefix through the two-stage pipeline and samples
the next token off the last real position's logits.  That is O(S) per
token instead of the paged engine's O(1), but it needs *no KV state on
any stage* — the pipeline stays a pure function of (params, tokens),
which is what lets one pool span a device group with nothing to
mirror, checkpoint, or scrub.  The right pool for long-tail
wide-model/short-sequence traffic; paged pools stay the throughput
path.

Serves the same ``submit`` / ``step`` / ``flush`` / ``done`` /
``stats`` API as the engines, so
:class:`~repro.serving.executor.EngineExecutor` drives it unchanged
and a ``PoolSpec(pipeline_stages=2)`` drops it into any fleet.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.partition import PartitionPlan
from repro.core.pipeline import (lm_two_stage_fns, pipeline_apply,
                                 split_lm_params_for_stages)
from repro.models import transformer as T
from repro.models.layers import embed
from repro.runtime.sampling import GREEDY, sample_logits
from repro.runtime.serve import Request, _require_prompt


@dataclass
class _StageSlot:
    req: Request
    gen: List[int] = field(default_factory=list)
    remaining: int = 0


class StageAxisEngine:
    """A decode server whose forward runs the two-stage pipeline over a
    ``("stage",)`` mesh of ``num_stages`` local devices."""

    def __init__(self, params, cfg, num_stages: int = 2,
                 max_slots: int = 4, prompt_len: int = 16,
                 max_len: int = 24, block_size: int = 8,
                 plan: Optional[PartitionPlan] = None, tp: int = 1):
        if num_stages != 2:
            raise ValueError(
                f"stage-axis pools currently support exactly 2 stages "
                f"(the MPAI backbone/tail split); got {num_stages}")
        if len(jax.devices()) < num_stages:
            raise ValueError(
                f"pipeline_stages={num_stages} needs {num_stages} local "
                f"devices, found {len(jax.devices())}; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{num_stages}")
        self.cfg = cfg
        self.params = params
        self.num_stages = num_stages
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.block_size = block_size
        self.tp = tp

        period = T.pattern_period(cfg)
        n_super = cfg.num_layers // period
        if plan is None:
            if n_super % 2:
                raise ValueError(
                    f"stage-axis pools need an even super-block count to "
                    f"split two ways; {cfg.num_layers} layers / period "
                    f"{period} = {n_super}")
            plan = PartitionPlan.mpai(cfg.num_layers,
                                      split=(n_super // 2) * period)
        self.plan = plan.align_to_period(period, cfg.num_layers)
        self.mesh = Mesh(np.array(jax.devices()[:num_stages]), ("stage",))
        s0, s1, _ = lm_two_stage_fns(cfg, self.plan, tp)
        self._fns = (s0, s1)
        self._stacked = split_lm_params_for_stages(params, cfg, self.plan,
                                                   period)
        self._emb_dtype = self.plan.embed_policy.precision.compute_dtype
        self._decode = jax.jit(self._decode_impl)

        self.queue: List[Request] = []
        self.slots: List[Optional[_StageSlot]] = [None] * max_slots
        self.done: Dict[int, Request] = {}
        self.on_token: Optional[Callable[[int, int], None]] = None
        self.reset_stats()

    # ------------------------------------------------------------------
    # pipelined forward: one token per active slot per call
    # ------------------------------------------------------------------
    def _decode_impl(self, tokens, lengths, temps, topks, seeds, steps):
        """tokens [n_micro, S] int32 (right-padded — causality makes the
        pad positions invisible to the last real logit); lengths
        [n_micro].  Each slot is one microbatch of the stage pipeline.
        Returns [n_micro] int32 next tokens."""
        S = tokens.shape[1]
        x = embed(self.params["embed"], tokens, self._emb_dtype)
        xs = x[:, None]                       # [n_micro, 1, S, d]
        outs = pipeline_apply(
            self.mesh, "stage", self._fns, self._stacked, xs,
            hidden_shape=(1, S, self.cfg.d_model),
            out_shape=(1, S, self.cfg.vocab_size),
            hidden_dtype=jnp.bfloat16, out_dtype=jnp.float32)
        idx = jnp.clip(lengths - 1, 0, S - 1)
        logits = jnp.take_along_axis(
            outs[:, 0], idx[:, None, None], axis=1)[:, 0]    # [n_micro, V]
        return sample_logits(logits, temps, topks, seeds, steps)

    # ------------------------------------------------------------------
    # server API
    # ------------------------------------------------------------------
    def padded_prompt_len(self, s: int) -> int:
        return max(s, self.prompt_len)

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def occupancy(self) -> float:
        return sum(s is not None for s in self.slots) / self.max_slots

    def submit(self, req: Request) -> None:
        _require_prompt(req, "stage-axis engine")
        n = int(req.prompt.shape[0])
        if n > self.prompt_len:
            raise ValueError(
                f"request {req.rid}: prompt of {n} tokens exceeds this "
                f"stage-axis pool's prompt_len bucket of "
                f"{self.prompt_len} (chunked prefill is a paged-pool "
                f"feature)")
        assert n + req.max_new <= self.max_len, \
            (req.rid, n, req.max_new, self.max_len)
        self.queue.append(req)

    def step(self) -> List[Request]:
        completed: List[Request] = []
        t0 = time.perf_counter()
        for i in range(self.max_slots):        # admit into free slots
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = _StageSlot(req, [], req.max_new)
                self.prefill_tokens += int(req.prompt.shape[0])
        self.admit_s += time.perf_counter() - t0
        active = [i for i in range(self.max_slots)
                  if self.slots[i] is not None]
        if not active:
            return completed
        S = self.max_len
        tokens = np.zeros((self.max_slots, S), np.int32)
        lengths = np.ones(self.max_slots, np.int32)
        temps = np.zeros(self.max_slots, np.float32)
        topks = np.zeros(self.max_slots, np.int32)
        seeds = np.zeros(self.max_slots, np.int32)
        steps = np.zeros(self.max_slots, np.int32)
        for i in active:
            s = self.slots[i]
            seq = list(map(int, s.req.prompt)) + s.gen
            tokens[i, :len(seq)] = seq
            lengths[i] = len(seq)
            sp = s.req.sampling or GREEDY
            temps[i], topks[i] = sp.temperature, sp.top_k
            seeds[i], steps[i] = sp.seed, len(s.gen)
        t0 = time.perf_counter()
        nxt = np.asarray(self._decode(jnp.asarray(tokens),
                                      jnp.asarray(lengths),
                                      jnp.asarray(temps),
                                      jnp.asarray(topks),
                                      jnp.asarray(seeds),
                                      jnp.asarray(steps)))
        self.decode_s += time.perf_counter() - t0
        self.decode_steps += 1
        self.occupancy_sum += self.occupancy
        for i in active:
            s = self.slots[i]
            tok = int(nxt[i])
            s.gen.append(tok)
            s.remaining -= 1
            self.total_tokens += 1
            self.decode_tokens += 1
            if self.on_token is not None:
                self.on_token(s.req.rid, tok)
            if s.remaining == 0:
                s.req.output = np.asarray(s.gen, np.int32)
                self.done[s.req.rid] = s.req
                completed.append(s.req)
                self.slots[i] = None
        return completed

    def flush(self) -> List[Request]:
        """Blocking form: run until at least one request completes."""
        if not self.pending:
            return []
        while True:
            done = self.step()
            if done:
                return done

    def stats(self) -> Dict[str, float]:
        steps = max(self.decode_steps, 1)
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "mean_occupancy": self.occupancy_sum / steps,
                "decode_tokens": self.decode_tokens,
                "decode_s": self.decode_s,
                "admit_s": self.admit_s,
                "prefill_tokens": self.prefill_tokens,
                "deferrals": self.deferrals,
                "num_stages": self.num_stages}

    def reset_stats(self) -> None:
        self.total_tokens = 0
        self.decode_steps = 0
        self.occupancy_sum = 0.0
        self.decode_tokens = 0
        self.decode_s = 0.0
        self.admit_s = 0.0
        self.prefill_tokens = 0
        self.deferrals = 0                 # no paged admission -> always 0
