"""repro.serving — the one serving API over the heterogeneous fleet.

MPAI's architectural claim is a *single submission interface* in front
of co-processors: the host dispatches each inference to whichever
accelerator operating point fits, and the caller never learns which
device ran it.  This package is that front door for the whole repo —
declarative fleet specs, one client, streaming responses:

    from repro.serving import FleetSpec, PoolSpec

    spec = FleetSpec(pools=[PoolSpec("lm", ("tpu_v5e_bf16",),
                                     backend="engine", max_slots=4,
                                     max_new=16)],
                     workload="transformer", arch="qwen3-14b")
    client = spec.build()
    handle = client.submit(prompt_tokens, slo="offline", max_new=16)
    for tok in handle.stream():          # tokens arrive per decode step
        ...
    print(handle.result(), client.telemetry)

Layers (one module per concern)::

    spec.py     FleetSpec / PoolSpec / FaultSpec — fleets as data; dict/
                JSON round-trip; build() assembles Router + pools +
                engines; make_server() is the only sanctioned decode-
                server constructor; build_pool() the shared per-pool
                assembly path (spec build and live growth)
    client.py   ServingClient (submit/step/drain + fleet clock), live
                fleet mutation (add_pool / retire_pool / set_capacity —
                retirements drain gracefully), and ResponseHandle
                (.result / .stream / .telemetry)
    executor.py EngineExecutor — adapts the continuous-batching engine
                to the router's executor protocol: LMWork payloads,
                per-token relay, decode-only tokens/s, OutOfBlocks
                deferrals as backpressure telemetry
    traffic.py  Poisson open-loop driver shared by launchers,
                benchmarks, and tests

Everything else — ``launch/serve.py``, ``launch/route.py``, the
examples, and the serving benchmarks — goes through this package; no
other call site constructs ``Router``, ``ContinuousBatchingEngine``, or
the windowed baseline directly.  The orbit control plane
(``repro.orbit``: global energy cap + telemetry-driven autoscaler)
attaches on top of a built client via ``OrbitSpec.attach`` and drives
the live-mutation operations above.
"""
from repro.router.slo import SLO_CLASSES, SLOClass
from repro.runtime.sampling import GREEDY, SamplingParams
from repro.serving.client import Response, ResponseHandle, ServingClient
from repro.serving.executor import EngineExecutor, LMWork
from repro.serving.spec import (DEFAULT_SLOS, FaultSpec, FleetSpec,
                                PoolSpec, build_pool, make_server)
from repro.serving.traffic import TrafficTrace, open_loop, poisson_arrivals

__all__ = [
    "DEFAULT_SLOS", "EngineExecutor", "FaultSpec", "FleetSpec", "GREEDY",
    "LMWork", "PoolSpec", "Response", "ResponseHandle", "SLOClass",
    "SLO_CLASSES", "SamplingParams", "ServingClient", "TrafficTrace",
    "build_pool", "make_server", "open_loop", "poisson_arrivals",
]
