"""Declarative fleet specs: one config path for launchers, examples,
benchmarks, and tests.

A :class:`FleetSpec` describes a serving fleet — pools (cost-model
priced, engine-backed, or the windowed baseline), the cost-model
workload the router's Pareto frontier is scheduled over, SLO classes,
and scheduled faults — as plain data.  ``to_dict`` / ``from_dict``
round-trip losslessly through JSON, so a launcher flag set, a benchmark
scenario, and a test fixture are literally the same object.
``FleetSpec.build()`` assembles the live system (Router + pools +
executors + FailoverController) and returns the one front door:
:class:`~repro.serving.client.ServingClient`.

MPAI's single-submission-interface story in code: the caller writes a
spec and submits prompts; which accelerator profile, pool, or engine
slot serves each request is the router's business.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import asdict, dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from repro.router.slo import SLOClass

# SLO classes every fleet understands in addition to router.slo's
# mission classes: "offline" is the relaxed default for LM serving.
DEFAULT_SLOS: Dict[str, SLOClass] = {
    "offline": SLOClass("offline", max_latency_s=600.0),
}


def _reject_unknown_keys(cls, d: Dict, label: str) -> None:
    """from_dict guard: a typo'd key must not silently vanish into a
    TypeError traceback deep in dataclass __init__."""
    valid = {f.name for f in cls.__dataclass_fields__.values()}
    unknown = sorted(set(d) - valid)
    if unknown:
        raise ValueError(
            f"{label}.from_dict: unknown key(s) {unknown}; valid keys "
            f"are {sorted(valid)}")


@dataclass
class PoolSpec:
    """One accelerator pool: capability, batching window, and backend.

    ``backend``:
      * ``"costmodel"`` — batches priced by the roofline cost model on
        the router's virtual clock (routing-fabric experiments);
      * ``"engine"`` — a real :class:`ContinuousBatchingEngine` decode
        pool (falls back to the windowed loop, with a warning, for
        stacks paged decode cannot serve);
      * ``"windowed"`` — the legacy windowed baseline, kept for
        engine-vs-windowed benchmark comparisons.

    ``max_prompt_len`` (engine pools) admits prompts beyond the
    ``prompt_len`` bucket via chunked paged prefill — it sizes the KV
    table, not a compiled shape, so only block budget bounds it.

    ``prefill_backend="engine"`` disaggregates the pool into MPAI's
    co-processing split: a prefill-class engine (the DPU analogue,
    running ``prefill_plan`` — e.g. ``"mpai"`` for the int8 cost-model
    plan) fills paged KV blocks and hands them to the decode-class
    engine (the VPU analogue) over mirrored pools.  The fleet routes
    one pool; telemetry and the orbit energy bucket see two —
    ``<name>`` (decode) and ``<name>.prefill`` — each charged its own
    stage (``prefill_energy_scale`` scales the plan's per-token energy
    for the cheaper prefill engine).
    """
    name: str
    profiles: Tuple[str, ...]
    backend: str = "costmodel"
    capacity: int = 1
    max_window: int = 4
    max_wait_s: float = 0.02
    # engine/windowed backends only:
    max_slots: int = 4
    prompt_len: int = 16
    max_new: int = 8                     # default per-request budget
    block_size: int = 8
    num_blocks: Optional[int] = None     # None -> slots * ceil(max_len/block)
    plan: Optional[str] = None           # None/"bf16" | "mpai"
    plan_split: Optional[int] = None     # mpai split point override
    max_prompt_len: Optional[int] = None  # None -> prompt_len bucket only
    prefill_chunk: Optional[int] = None   # chunk width; None -> prompt_len
    # prefill/decode disaggregation (engine backend only):
    prefill_backend: Optional[str] = None     # None | "engine"
    prefill_plan: Optional[str] = None        # None/"bf16" | "mpai"
    prefill_energy_scale: float = 0.5         # DPU-vs-VPU per-token energy
    # pod-scale fan-out: N decode-shard engines behind one prefill stage
    # (requires prefill_backend="engine"); every handoff crosses the
    # seam in PrefillHandoff wire form and the least-loaded shard
    # imports it.  1 == the classic unsharded co-processing split.
    decode_shards: int = 1
    # stage-axis execution: span this pool's decode across a device
    # group via core.pipeline.pipeline_apply (engine backend only;
    # mutually exclusive with disaggregation — a staged pool is one
    # consumer).  None -> single-device engine.
    pipeline_stages: Optional[int] = None
    # radiation hardening (engine backends): per-block KV integrity
    # digests + fused decode-path verification + no-progress watchdog.
    # Off by default — hardened output with no faults is bit-identical
    # to hardening-off, but the checksum adds a small decode cost.
    # ``build()`` flips it on automatically for pools targeted by a
    # data-plane FaultSpec (kind != "pool").
    harden: bool = False
    scrub_blocks: int = 2                # background scrub budget / tick
    watchdog_steps: int = 8              # decode steps before a stalled
                                         # slot is evicted and replayed

    def __post_init__(self):
        if self.backend not in ("costmodel", "engine", "windowed"):
            raise ValueError(f"unknown pool backend {self.backend!r}")
        if self.prefill_backend not in (None, "engine"):
            raise ValueError(
                f"unknown prefill backend {self.prefill_backend!r}")
        if self.prefill_backend is not None and self.backend != "engine":
            raise ValueError(
                f"pool {self.name!r}: prefill_backend requires "
                f"backend='engine' (got {self.backend!r})")
        self.profiles = tuple(self.profiles)

    def validate(self) -> "PoolSpec":
        """Fail fast on values ``build()`` would only trip over deep in
        engine assembly (or worse, serve wrong).  Raises ``ValueError``
        with the pool name and the offending field; returns self so
        call sites can chain."""
        def bad(msg: str) -> ValueError:
            return ValueError(f"pool {self.name!r}: {msg}")
        if not self.name:
            raise ValueError("pool name must be non-empty")
        if not self.profiles:
            raise bad("profiles must be non-empty — the router can "
                      "never route to a pool that serves nothing")
        if self.capacity < 1:
            raise bad(f"capacity must be >= 1 (got {self.capacity})")
        if self.max_window < 1:
            raise bad(f"max_window must be >= 1 (got {self.max_window})")
        if self.max_wait_s < 0:
            raise bad(f"max_wait_s must be >= 0 (got {self.max_wait_s})")
        if self.max_slots < 1:
            raise bad(f"max_slots must be >= 1 (got {self.max_slots})")
        if self.prompt_len < 1:
            raise bad(f"prompt_len must be >= 1 (got {self.prompt_len})")
        if self.max_new < 1:
            raise bad(f"max_new must be >= 1 (got {self.max_new})")
        if self.block_size < 1:
            raise bad(f"block_size must be >= 1 (got {self.block_size})")
        if self.prefill_chunk is not None:
            if self.prefill_chunk < 1:
                raise bad(f"prefill_chunk must be positive "
                          f"(got {self.prefill_chunk})")
            if self.prefill_chunk % self.block_size != 0:
                raise bad(
                    f"prefill_chunk={self.prefill_chunk} must be a "
                    f"multiple of block_size={self.block_size} — chunked "
                    f"prefill writes whole KV blocks")
        if self.num_blocks is not None and self.num_blocks < 1:
            raise bad(f"num_blocks must be >= 1 (got {self.num_blocks})")
        if self.max_prompt_len is not None and self.max_prompt_len < 1:
            raise bad(f"max_prompt_len must be >= 1 "
                      f"(got {self.max_prompt_len})")
        if self.prefill_energy_scale < 0:
            raise bad(f"prefill_energy_scale must be >= 0 "
                      f"(got {self.prefill_energy_scale})")
        if self.decode_shards < 1:
            raise bad(f"decode_shards must be >= 1 "
                      f"(got {self.decode_shards})")
        if self.decode_shards > 1 and self.prefill_backend != "engine":
            raise bad(
                f"decode_shards={self.decode_shards} requires "
                f"prefill_backend='engine' — sharded decode is the "
                f"fan-out side of the co-processing split")
        if self.pipeline_stages is not None:
            if self.pipeline_stages < 2:
                raise bad(f"pipeline_stages must be >= 2 when set "
                          f"(got {self.pipeline_stages})")
            if self.backend != "engine":
                raise bad("pipeline_stages requires backend='engine'")
            if self.prefill_backend is not None or self.decode_shards > 1:
                raise bad(
                    "pipeline_stages is mutually exclusive with "
                    "prefill_backend/decode_shards — a staged pool is "
                    "one consumer spanning a device group")
            if self.max_prompt_len is not None:
                raise bad("pipeline_stages does not support "
                          "max_prompt_len (chunked prefill) yet")
        if self.scrub_blocks < 0:
            raise bad(f"scrub_blocks must be >= 0 (got "
                      f"{self.scrub_blocks}); 0 disables background scrub")
        if self.watchdog_steps < 1:
            raise bad(f"watchdog_steps must be >= 1 "
                      f"(got {self.watchdog_steps})")
        return self

    @property
    def chunk(self) -> int:
        """The prefill chunk grid (block-aligned; prompts pad to it)."""
        if self.prefill_chunk is not None:
            return self.prefill_chunk
        return max(self.block_size,
                   self.prompt_len // self.block_size * self.block_size)

    @property
    def max_len(self) -> int:
        # +2 floor keeps one decode step available for jit warm-up even
        # for max_new=1 pools.  max_prompt_len is a guarantee, not a
        # cap: it rounds UP to the chunk grid, so every prompt up to it
        # (and its grid remainder) actually fits the KV table
        prompt = self.prompt_len
        if self.max_prompt_len is not None and self.max_prompt_len > prompt:
            prompt = -(-self.max_prompt_len // self.chunk) * self.chunk
        return prompt + max(self.max_new, 2)

    def to_dict(self) -> Dict:
        d = asdict(self)
        d["profiles"] = list(self.profiles)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "PoolSpec":
        _reject_unknown_keys(cls, d, "PoolSpec")
        return cls(**{**d, "profiles": tuple(d["profiles"])})


@dataclass
class FaultSpec:
    """A scheduled upset (SEU) on the fleet's clock.

    ``kind`` picks the blast radius (see
    :class:`~repro.runtime.fault.PoolFault`): ``"pool"`` (default) is
    the control-plane fault — the pool loses profiles and in-flight
    work fails over; ``"kv_bitflip"`` / ``"slot_stall"`` /
    ``"handoff_loss"`` are data-plane faults delivered inside the
    pool's engine (``seed`` picks the bitflip site, ``slot`` the
    stalled slot) and require a hardened engine pool to be detected —
    ``build()`` hardens the target pool automatically.
    """
    pool: str
    at_s: float
    duration_s: float = math.inf
    lost_profiles: Tuple[str, ...] = ()
    kind: str = "pool"
    slot: int = 0                        # slot_stall target
    seed: int = 0                        # kv_bitflip site selector

    _KINDS = ("pool", "kv_bitflip", "slot_stall", "handoff_loss")

    def __post_init__(self):
        self.lost_profiles = tuple(self.lost_profiles)
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {self._KINDS})")

    def to_dict(self) -> Dict:
        d = asdict(self)
        d["lost_profiles"] = list(self.lost_profiles)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultSpec":
        _reject_unknown_keys(cls, d, "FaultSpec")
        return cls(**{**d, "lost_profiles": tuple(d.get("lost_profiles",
                                                        ()))})


@dataclass
class FleetSpec:
    """The whole fleet as data.  ``build()`` returns a ServingClient."""
    pools: List[PoolSpec]
    workload: str = "ursonet"            # "ursonet" | "transformer"
    arch: Optional[str] = None           # config-registry name (LM fleets)
    smoke: bool = True                   # reduced config for the arch
    seq_len: int = 512                   # cost-model pricing length
    accuracy_penalty: Dict[str, float] = field(default_factory=dict)
    cut_candidates: Optional[List[int]] = None
    slos: List[Dict] = field(default_factory=list)   # extra SLOClass kwargs
    faults: List[FaultSpec] = field(default_factory=list)
    dt: float = 0.002                    # clock tick for drive loops
    latency_headroom: float = 0.6
    trace: bool = False                  # flight recorder on from tick 0
    # bounded redispatch: RetryPolicy kwargs per SLO class name; the
    # "default" key replaces the router's fleet-wide default policy
    retry: Dict[str, Dict] = field(default_factory=dict)
    # client-side no-progress watchdog window (virtual seconds a
    # streaming request may go without a new token); None -> disabled
    watchdog_s: Optional[float] = None
    # SLO plane (repro.obs.slo.SLOSpec): objectives + burn-rate alert
    # windows; build() attaches the live engine.  None -> SLIs only.
    slo: Optional[object] = None

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "pools": [p.to_dict() for p in self.pools],
            "workload": self.workload,
            "arch": self.arch,
            "smoke": self.smoke,
            "seq_len": self.seq_len,
            "accuracy_penalty": dict(self.accuracy_penalty),
            "cut_candidates": (None if self.cut_candidates is None
                               else list(self.cut_candidates)),
            "slos": [dict(s) for s in self.slos],
            "faults": [f.to_dict() for f in self.faults],
            "dt": self.dt,
            "latency_headroom": self.latency_headroom,
            "trace": self.trace,
            "retry": {k: dict(v) for k, v in self.retry.items()},
            "watchdog_s": self.watchdog_s,
            "slo": None if self.slo is None else self.slo.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetSpec":
        d = dict(d)
        _reject_unknown_keys(cls, d, "FleetSpec")
        d["pools"] = [PoolSpec.from_dict(p) for p in d["pools"]]
        d["faults"] = [FaultSpec.from_dict(f) for f in d.get("faults", [])]
        if d.get("slo") is not None:
            from repro.obs.slo import SLOSpec
            d["slo"] = SLOSpec.from_dict(d["slo"])
        return cls(**d)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "FleetSpec":
        """Fail fast before any engine compiles.  Checks every pool,
        fleet-level clock/retry settings, and cross-references (fault
        targets, duplicate pool names).  Called by ``build()``."""
        if not self.pools:
            raise ValueError("FleetSpec needs at least one pool")
        names = [p.name for p in self.pools]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ValueError(f"duplicate pool name(s) {dupes} — telemetry "
                             f"and routing key pools by name")
        for p in self.pools:
            p.validate()
        if self.dt <= 0:
            raise ValueError(f"dt must be > 0 (got {self.dt})")
        if self.latency_headroom <= 0:
            raise ValueError(f"latency_headroom must be > 0 "
                             f"(got {self.latency_headroom})")
        if self.watchdog_s is not None and self.watchdog_s <= 0:
            raise ValueError(f"watchdog_s must be > 0 when set "
                             f"(got {self.watchdog_s})")
        from repro.router.dispatch import RetryPolicy
        retry_fields = {f.name for f in
                        RetryPolicy.__dataclass_fields__.values()}
        for slo_name, kw in self.retry.items():
            unknown = sorted(set(kw) - retry_fields)
            if unknown:
                raise ValueError(
                    f"retry[{slo_name!r}]: unknown RetryPolicy key(s) "
                    f"{unknown}; valid keys are {sorted(retry_fields)}")
            if kw.get("max_attempts", 1) < 1:
                raise ValueError(
                    f"retry[{slo_name!r}]: max_attempts must be >= 1 "
                    f"(got {kw['max_attempts']})")
            for k in ("backoff_s", "multiplier", "max_backoff_s"):
                if k in kw and kw[k] <= 0:
                    raise ValueError(
                        f"retry[{slo_name!r}]: {k} must be > 0 "
                        f"(got {kw[k]})")
        pool_names = set(names)
        for f in self.faults:
            if f.pool not in pool_names:
                raise ValueError(
                    f"fault targets unknown pool {f.pool!r}; fleet pools "
                    f"are {sorted(pool_names)}")
            if f.duration_s <= 0:
                raise ValueError(
                    f"fault on {f.pool!r}: duration_s must be > 0 "
                    f"(got {f.duration_s})")
        if self.slo is not None:
            self.slo.validate()
        return self

    # ------------------------------------------------------------------
    # assembly
    # ------------------------------------------------------------------
    def slo_classes(self) -> Dict[str, SLOClass]:
        out = dict(DEFAULT_SLOS)
        for kw in self.slos:
            slo = SLOClass(**kw)
            out[slo.name] = slo
        return out

    def _layer_costs(self, cfg=None):
        if self.workload == "ursonet":
            from repro.core.cost_model import layer_costs_from_convspecs
            from repro.models.cnn import ursonet_table1_layers
            return layer_costs_from_convspecs(ursonet_table1_layers())
        if self.workload == "transformer":
            from repro.core.cost_model import transformer_layer_costs
            if cfg is None:
                cfg = self._config()
            return transformer_layer_costs(cfg, seq_len=self.seq_len)
        raise ValueError(f"unknown workload {self.workload!r}")

    def _config(self):
        if self.arch is None:
            raise ValueError("transformer workload needs arch= (or pass "
                             "model=(cfg, params) to build())")
        from repro.configs import get_config
        return get_config(self.arch, smoke=self.smoke)

    def build(self, model=None, warm: bool = True):
        """Assemble the live fleet; returns a ServingClient.

        ``model`` — optional ``(cfg, params)`` shared by every engine/
        windowed pool (tests and benchmarks pass tiny hand-built
        configs); otherwise the arch registry + a seed-0 init provide
        it.  ``warm`` pre-compiles each LM server's jitted programs with
        a throwaway request so compile time never lands in the first
        routed batch's latency telemetry.
        """
        from repro.router import FailoverController, Router
        from repro.runtime.fault import PoolFault, PoolFaultInjector
        from repro.serving.client import ServingClient

        self.validate()
        cfg = params = None
        if any(p.backend != "costmodel" for p in self.pools):
            if model is not None:
                cfg, params = model
            else:
                import jax
                from repro.models import transformer as T
                cfg = self._config()
                params = T.model_init(jax.random.PRNGKey(0), cfg)
        layers = self._layer_costs(cfg)
        model = None if cfg is None else (cfg, params)

        # data-plane faults act inside an engine; detection needs the
        # hardening layer, so harden any targeted pool (a local replace
        # — the spec the caller holds is untouched)
        hardened_targets = {f.pool for f in self.faults if f.kind != "pool"}
        pools, engines, executors = [], {}, []
        for ps in self.pools:
            if ps.name in hardened_targets and not ps.harden:
                ps = replace(ps, harden=True)
            pool, engine, ex = build_pool(ps, layers, model=model, warm=warm)
            pools.append(pool)
            if engine is not None:
                engines[ps.name] = engine
                executors.append(ex)

        router = Router(layers, pools,
                        accuracy_penalty=self.accuracy_penalty or None,
                        cut_candidates=self.cut_candidates,
                        latency_headroom=self.latency_headroom)
        for ex in executors:
            if getattr(ex, "prefill_counters", None) is not None:
                # bind back: a reused stage name continues its history
                ex.prefill_counters = router.register_stage_pool(
                    ex.prefill_pool, ex.prefill_counters)
        if self.retry:
            from repro.router.dispatch import RetryPolicy
            for slo_name, kw in self.retry.items():
                policy = RetryPolicy(**kw)
                if slo_name == "default":
                    router.default_retry = policy
                else:
                    router.retry_policies[slo_name] = policy
        injector = PoolFaultInjector([
            PoolFault(f.pool, at_s=f.at_s, duration_s=f.duration_s,
                      lost_profiles=f.lost_profiles, kind=f.kind,
                      slot=f.slot, seed=f.seed) for f in self.faults])
        failover = FailoverController(router, injector)
        client = ServingClient(router, failover, engines=engines, spec=self,
                               dt=self.dt, slo_map=self.slo_classes(),
                               model=model, layers=layers,
                               watchdog_s=self.watchdog_s)
        for ex in executors:
            ex.on_token = client._on_token
        if self.trace:
            # after warmup: the throwaway compile requests never appear
            # in the flight recorder
            client.enable_tracing()
        if self.slo is not None:
            self.slo.attach(client)
        return client


def build_pool(ps: PoolSpec, layers, model=None, warm: bool = True):
    """Assemble one live pool from its spec.

    Returns ``(pool, engine, executor)``; ``engine``/``executor`` are
    None for cost-model pools.  ``FleetSpec.build()`` and live fleet
    growth (:meth:`~repro.serving.client.ServingClient.add_pool`, the
    orbit autoscaler's seam) share this single construction path, so a
    pool added mid-flight is indistinguishable from one built at spec
    time.  ``model`` is the ``(cfg, params)`` pair engine/windowed
    backends decode with.
    """
    from repro.router import AcceleratorPool, CostModelExecutor
    from repro.router.telemetry import PoolCounters
    from repro.serving.executor import EngineExecutor

    ps.validate()          # live fleet growth skips FleetSpec.validate()
    engine = engine_ex = None
    if ps.backend == "costmodel":
        ex = CostModelExecutor(layers)
    else:
        if model is None:
            raise ValueError(
                f"pool {ps.name!r} needs a model: the fleet was built "
                f"without one (no LM pools at build time); pass model= "
                f"or include an engine pool in the original FleetSpec")
        cfg, params = model
        engine = make_server(cfg, params, ps, warm=warm)
        kw = {}
        if ps.prefill_backend is not None:
            # disaggregated pool: the prefill stage gets its own named
            # counters so dispatch/energy telemetry charges each stage
            # to its own pool (registered with the router by the caller)
            kw = dict(prefill_pool=f"{ps.name}.prefill",
                      prefill_counters=PoolCounters(),
                      prefill_energy_scale=ps.prefill_energy_scale)
        ex = engine_ex = EngineExecutor(engine, max_new=ps.max_new, **kw)
    pool = AcceleratorPool(ps.name, ps.profiles, ex,
                           capacity=ps.capacity,
                           max_window=ps.max_window,
                           max_wait_s=ps.max_wait_s,
                           shards=ps.decode_shards)
    if engine_ex is not None:
        engine_ex.counters = pool.counters
    return pool, engine, engine_ex


def make_server(cfg, params, spec: PoolSpec, warm: bool = True):
    """Construct the LM server a PoolSpec describes.

    The facade-sanctioned constructor for decode servers —
    ``spec.build()`` and the decode benchmark both come through here, so
    no call site outside ``repro.serving`` touches the engine classes
    directly.  ``backend="engine"`` falls back to the windowed loop
    (with a warning) for stacks paged decode cannot serve, mirroring the
    old launcher behavior.
    """
    import numpy as np

    from repro.runtime.sampling import SamplingParams
    from repro.runtime.serve import (ContinuousBatchingEngine, CoProcServer,
                                     Request, WindowedBaselineServer,
                                     engine_or_windowed)
    plan = _resolve_plan(spec, cfg, spec.plan)
    hkw = dict(harden=spec.harden, watchdog_steps=spec.watchdog_steps,
               scrub_blocks=spec.scrub_blocks)
    if spec.backend == "engine" and spec.prefill_backend == "engine":
        # MPAI co-processing split: a prefill-class engine under its own
        # (typically cheaper) precision plan fills paged blocks, the
        # decode-class engine imports them over a mirrored pool.  The
        # prefill worker is single-slot (the handoff is synchronous) and
        # sized to one max-length prompt; disaggregation has no windowed
        # fallback — stacks paged decode cannot serve cannot split either
        prefill = ContinuousBatchingEngine(
            params, cfg, plan=_resolve_plan(spec, cfg, spec.prefill_plan),
            max_slots=1, prompt_len=spec.prompt_len, max_len=spec.max_len,
            block_size=spec.block_size, prefill_chunk=spec.prefill_chunk,
            **hkw)
        # decode_shards > 1: N mirrored decode engines behind the one
        # prefill stage; CoProcServer routes each wire handoff to the
        # least-loaded shard
        decodes = [ContinuousBatchingEngine(
            params, cfg, plan=plan, max_slots=spec.max_slots,
            prompt_len=spec.prompt_len, max_len=spec.max_len,
            block_size=spec.block_size, num_blocks=spec.num_blocks, **hkw)
            for _ in range(spec.decode_shards)]
        srv = CoProcServer(prefill, decodes)
    elif spec.backend == "engine" and spec.pipeline_stages is not None:
        # stage-axis pool: decode spans a device group via the GPipe
        # schedule in core.pipeline (no windowed fallback — a staged
        # pool that cannot build should fail loudly, not degrade)
        from repro.serving.stage_executor import StageAxisEngine
        srv = StageAxisEngine(
            params, cfg, num_stages=spec.pipeline_stages,
            max_slots=spec.max_slots, prompt_len=spec.prompt_len,
            max_len=spec.max_len, block_size=spec.block_size)
    elif spec.backend == "engine":
        srv = engine_or_windowed(
            params, cfg, plan=plan, max_slots=spec.max_slots,
            prompt_len=spec.prompt_len, max_len=spec.max_len,
            block_size=spec.block_size, num_blocks=spec.num_blocks,
            prefill_chunk=spec.prefill_chunk, **hkw,
            on_fallback=lambda e: warnings.warn(
                f"pool {spec.name!r}: paged decode unavailable ({e}); "
                f"falling back to the windowed baseline"))
    else:
        srv = WindowedBaselineServer(params, cfg, plan=plan,
                                     max_batch=spec.max_slots,
                                     prompt_len=spec.prompt_len,
                                     max_len=spec.max_len)
    if warm:
        # throwaway requests compile every serving program before
        # traffic: greedy prefill+decode, and (engine only) the sampled
        # admit/decode variants, so no routed batch ever pays XLA
        # compile time into the latency telemetry
        srv.submit(Request(-1, np.array([1, 2], np.int32), max_new=2))
        srv.flush()
        if isinstance(srv, (ContinuousBatchingEngine, CoProcServer)):
            srv.submit(Request(-2, np.array([1, 2], np.int32), max_new=2,
                               sampling=SamplingParams(temperature=1.0,
                                                       seed=0)))
            srv.flush()
        if (isinstance(srv, ContinuousBatchingEngine)
                and spec.max_prompt_len is not None
                and spec.max_prompt_len > spec.prompt_len
                and srv.padded_prompt_len(spec.prompt_len + 1) + 2
                <= srv.max_len):
            # over-bucket pool: compile the chunked-prefill program too
            srv.submit(Request(-3, np.arange(
                1, spec.prompt_len + 2, dtype=np.int32), max_new=2))
            srv.flush()
        srv.reset_stats()
    return srv


def _resolve_plan(spec: PoolSpec, cfg, name: Optional[str]):
    if name in (None, "bf16"):
        return None
    if name == "mpai":
        from repro.core import qat
        from repro.core.partition import PartitionPlan
        kw = {} if spec.plan_split is None else {"split": spec.plan_split}
        return qat.serve_plan(PartitionPlan.mpai(cfg.num_layers, **kw))
    raise ValueError(f"unknown pool plan {name!r}")
