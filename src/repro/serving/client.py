"""The serving front door: submit requests, get handles, stream tokens.

:class:`ServingClient` is the single entry point over the whole
heterogeneous fleet (MPAI's one-submission-interface).  It owns the
fleet's clock: ``submit()`` admits a request through the router at the
current virtual time, ``step()`` advances one tick (fault injection +
pool progress), and both :meth:`ResponseHandle.result` and
:meth:`ResponseHandle.stream` drive that clock themselves, so callers
never touch Router/FailoverController directly.

Per-token streaming: engine-backed pools relay every sampled token
(rid, token, engine decode step) the step it is produced; the handle
buffers them, ``stream()`` yields them in order, and the step stamps
let tests assert tokens really arrived incrementally across decode
steps rather than at batch completion.  Pools without a token hook (the
windowed baseline, cost-model pools) backfill the buffer at completion,
so ``stream()``/``result()`` behave identically everywhere — just
without mid-batch granularity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.obs.timeseries import FleetTimeSeries
from repro.router import FailoverController, Router, RouterRequest
from repro.router.slo import SLO_CLASSES, SLOClass
from repro.runtime.sampling import SamplingParams
from repro.serving.executor import LMWork


@dataclass
class Response:
    """Terminal record for one request."""
    rid: int
    admitted: bool
    tokens: Optional[np.ndarray]         # None for cost-model requests
    latency_s: Optional[float]
    violated: bool
    dropped: bool
    pool: Optional[str]


class ResponseHandle:
    """Caller's view of one in-flight request."""

    def __init__(self, client: "ServingClient", rreq: RouterRequest,
                 work: Optional[LMWork], admitted: bool):
        self._client = client
        self._rreq = rreq
        self._work = work
        self.admitted = admitted
        self._tokens: List[int] = []
        self._token_steps: List[Optional[int]] = []
        self._reroutes_seen = 0
        self._epoch = 0            # bumps when a reroute clears the buffer

    def _sync_reroute(self) -> None:
        """An SEU destroyed the in-flight decode; the re-dispatched
        request restarts its stream from token 0 on the new pool, so the
        buffer resets before anything else reads or extends it.  Every
        buffer access funnels through here: ``_backfill`` used to slice
        ``output[len(self._tokens):]`` against the STALE pre-reroute
        buffer, silently dropping the re-served stream's first tokens."""
        if self._rreq.rerouted != self._reroutes_seen:
            self._reroutes_seen = self._rreq.rerouted
            self._epoch += 1
            self._tokens.clear()
            self._token_steps.clear()

    # fed by the engine's per-token callback, via the client
    def _push(self, tok: int, step: Optional[int]) -> None:
        self._sync_reroute()
        self._tokens.append(int(tok))
        self._token_steps.append(step)

    def _backfill(self) -> None:
        """Hook-less backends deliver tokens only at completion."""
        self._sync_reroute()
        out = None if self._work is None else self._work.output
        if out is not None:
            for tok in np.asarray(out)[len(self._tokens):]:
                self._push(int(tok), None)

    @property
    def rid(self) -> int:
        return self._rreq.rid

    @property
    def done(self) -> bool:
        return (not self.admitted or self._rreq.dropped
                or self._rreq.done_s is not None)

    @property
    def dropped(self) -> bool:
        return self._rreq.dropped

    @property
    def tokens(self) -> List[int]:
        """Tokens received so far (does not advance the fleet)."""
        self._sync_reroute()       # never expose a stale pre-reroute buffer
        if self.done:
            self._backfill()       # hook-less backends deliver at the end
        return list(self._tokens)

    @property
    def token_steps(self) -> List[Optional[int]]:
        """Engine decode-step stamp per received token (None = delivered
        at completion by a hook-less backend)."""
        self._sync_reroute()
        if self.done:
            self._backfill()
        return list(self._token_steps)

    def result(self, max_s: float = 600.0) -> Response:
        """Drive the fleet until this request completes (or is dropped)."""
        while not self.done:
            self._client.step()
            if self._client.now > max_s:
                raise RuntimeError(f"request {self.rid} did not complete "
                                   f"by t={max_s}s")
        self._backfill()
        r = self._rreq
        tokens = (np.asarray(self._tokens, np.int32)
                  if self._work is not None else None)
        latency = None if r.done_s is None else r.done_s - r.arrival_s
        return Response(r.rid, self.admitted, tokens, latency,
                        violated=r.violated, dropped=r.dropped, pool=r.pool)

    def stream(self, max_s: float = 600.0) -> Iterator[int]:
        """Yield tokens as they arrive, driving the fleet in between.

        Exactly-once across recovery: the cursor counts tokens
        *delivered to the consumer*, and a failover reroute clears the
        buffer while the re-served decode regrows it bit-identically
        (deterministic sampling), so delivery resumes at the first
        not-yet-yielded token — the consumer never sees a duplicate of
        the prefix it already consumed, and never skips a token of the
        re-served tail."""
        i = 0
        while True:
            self._sync_reroute()
            while i < len(self._tokens):
                yield self._tokens[i]
                i += 1
            if self.done:
                self._backfill()
                while i < len(self._tokens):
                    yield self._tokens[i]
                    i += 1
                return
            self._client.step()
            if self._client.now > max_s:
                raise RuntimeError(f"request {self.rid} stalled at "
                                   f"t={max_s}s")

    def trace(self) -> Optional[Dict]:
        """This request's span tree from the flight recorder (None when
        tracing was off at submission): the root ``request`` span with
        queue/serve/prefill/decode stages nested by containment, plus
        the terminal ``outcome``.  See :mod:`repro.obs`."""
        return self._client.tracer.trace(self.rid)

    @property
    def telemetry(self) -> Dict:
        """Per-request slice of the fleet's bookkeeping."""
        if self.done:
            self._backfill()
        r = self._rreq
        return {
            "rid": r.rid,
            "admitted": self.admitted,
            "pool": r.pool,
            # the co-processing split, per request: a disaggregated pool
            # stamps which stage pool prefilled the prompt; the routed
            # pool itself runs decode (they coincide on unified pools)
            "prefill_pool": (getattr(self._work, "prefill_pool", None)
                             if self._work is not None else None),
            "decode_pool": r.pool,
            "dropped": r.dropped,
            "violated": r.violated,
            "rerouted": r.rerouted,
            "deferred": r.deferred,
            "arrival_s": r.arrival_s,
            "done_s": r.done_s,
            "latency_s": (None if r.done_s is None
                          else r.done_s - r.arrival_s),
            # golden-signal stamps: time to first token the consumer saw
            # (falls back to completion on hook-less pools) and the
            # end-to-end latency under its SLI name
            "ttft_s": (None if r.first_out_s is None
                       else r.first_out_s - r.arrival_s),
            "e2e_s": (None if r.done_s is None
                      else r.done_s - r.arrival_s),
            "tokens": len(self._tokens),
        }


class ServingClient:
    """One front door over the fleet; constructed by ``FleetSpec.build``."""

    def __init__(self, router: Router,
                 failover: Optional[FailoverController] = None,
                 engines: Optional[Dict[str, object]] = None,
                 spec=None, dt: float = 0.002,
                 slo_map: Optional[Dict[str, SLOClass]] = None,
                 model=None, layers=None,
                 watchdog_s: Optional[float] = None):
        self.router = router
        self.failover = failover
        self.engines = dict(engines or {})   # pool name -> LM server
        self.spec = spec
        self.dt = dt
        self.now = 0.0
        self._next_rid = 0
        self._handles: Dict[int, ResponseHandle] = {}
        self._slos = dict(slo_map or {})
        # live-mutation state: the model/layers new pools are built from,
        # and pools draining toward graceful retirement
        self._model = model                  # (cfg, params) or None
        self._layers = layers if layers is not None else list(router.layers)
        self._retiring: set = set()
        # orbit control plane (repro.orbit.FleetController), if attached
        self.controller = None
        # SLO judgment plane (repro.obs.slo.SLOEngine), if attached
        self.slo_engine = None
        # flight recorder: the router's tracer (disabled until
        # enable_tracing) plus the always-on fleet time-series ring
        self.tracer = router.telemetry.tracer
        self.timeseries = FleetTimeSeries()
        # radiation hardening: deliver data-plane fault events into the
        # target pool's engine, and back-stop the engine watchdogs with a
        # fleet-level no-progress check over live handles
        if failover is not None:
            failover.data_plane = self._apply_data_plane_fault
        self.watchdog_s = watchdog_s         # None -> disabled
        self._watch: Dict[int, list] = {}    # rid -> [tokens, since]

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def resolve_slo(self, slo) -> SLOClass:
        if isinstance(slo, SLOClass):
            return slo
        if slo in self._slos:
            return self._slos[slo]
        if slo in SLO_CLASSES:
            return SLO_CLASSES[slo]
        raise KeyError(f"unknown SLO class {slo!r}; known: "
                       f"{sorted(self._slos) + sorted(SLO_CLASSES)}")

    def submit(self, prompt=None, slo="offline", *,
               max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               arrival: Optional[float] = None,
               rid: Optional[int] = None) -> ResponseHandle:
        """Admit one request at the current fleet time.

        ``prompt``: token array for LM pools (or a prebuilt
        :class:`LMWork`); None routes a cost-model (vision) request.
        Rejection at admission (no plan fits the SLO at current load) is
        surfaced on the handle, not raised.
        """
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        work = None
        if isinstance(prompt, LMWork):
            work = prompt
        elif prompt is not None:
            work = LMWork(np.asarray(prompt, np.int32), max_new=max_new,
                          sampling=sampling)
        if work is not None and work.prompt.shape[0] == 0:
            # fail fast with an actionable error, mirroring the
            # oversized-max_new check below: a zero-length prompt used
            # to slip into a pool's batch and crash it mid-admission
            # (the -0 slice selects the whole row), taking the already-
            # batched neighbors down with it
            raise ValueError(
                "empty prompt: LM serving needs at least one prompt "
                "token to prefill; submit prompt=None to route a "
                "cost-model (vision) request instead")
        if work is not None and work.max_new is not None and self.engines:
            # fail fast with an actionable error instead of counting the
            # request admitted and crashing inside a pool's batch.  The
            # bound is the SMALLEST engine pool's budget: dispatch is
            # payload-blind, so any LM pool may end up serving this
            # request (routing by max_new is future work)
            budget = min(e.max_len - e.prompt_len
                         for e in self.engines.values())
            if work.max_new > budget:
                raise ValueError(
                    f"max_new={work.max_new} exceeds the smallest LM "
                    f"pool's budget ({budget}), and dispatch does not "
                    f"route by max_new; raise PoolSpec.max_new — it "
                    f"sizes the per-request KV allocation")
        if work is not None and self.engines:
            # same fast-fail for prompts: dispatch is payload-blind, so
            # the (padded) prompt plus requested max_new must fit EVERY
            # LM pool's KV table — each pool's own chunk grid decides
            # the padding, so ask the servers rather than guessing.
            # (max_new=None resolves to the pool default, which sizes
            # max_len by construction and can never overflow.)
            s = int(work.prompt.shape[0])
            mn = max(work.max_new or 1, 1)
            for name, e in self.engines.items():
                pad_fn = getattr(e, "padded_prompt_len", None)
                if pad_fn is None and s > e.prompt_len:   # windowed pool
                    raise ValueError(
                        f"prompt of {s} tokens exceeds pool {name!r}'s "
                        f"windowed prompt_len bucket of {e.prompt_len}; "
                        f"use an engine pool (chunked paged prefill "
                        f"lifts the bucket limit)")
                padded = e.prompt_len if pad_fn is None else pad_fn(s)
                if padded + mn > e.max_len:
                    raise ValueError(
                        f"prompt of {s} tokens ({padded} padded) + "
                        f"max_new={mn} cannot fit pool {name!r}'s "
                        f"context of {e.max_len}; raise "
                        f"PoolSpec.max_prompt_len (chunked paged "
                        f"prefill sizes the KV table, not a compiled "
                        f"shape) or shrink the request")
        rreq = RouterRequest(rid, self.resolve_slo(slo),
                             self.now if arrival is None else arrival,
                             payload=work)
        self.tracer.begin_request(
            rreq.rid, rreq.arrival_s, slo=rreq.slo.name,
            kind="lm" if work is not None else "cost",
            prompt=None if work is None else int(work.prompt.shape[0]),
            max_new=None if work is None else work.max_new)
        # the orbit controller (if attached) gates admission on the
        # global energy bucket: deferrable work parks until sunlight
        # returns; rejection is the dry-battery last resort
        verdict = ("dispatch" if self.controller is None
                   else self.controller.admission(rreq))
        if verdict == "dispatch":
            admitted = self.router.submit(rreq, self.now)
            if (admitted and self.controller is not None
                    and work is not None):
                # charge the expected plan energy at submit time — the
                # bucket sees admitted load before its tokens decode;
                # the controller refunds the estimate when the request
                # settles and the real metered spend has drained
                self.controller.prepay(rreq, work.max_new)
        elif verdict == "defer":
            self.controller.defer(rreq, self.now)
            admitted = True                  # accepted; dispatches later
        else:                                # "reject"
            self.router.telemetry.record_rejection(rreq.slo.name, self.now)
            self.router.telemetry.energy_rejected += 1
            # reason ledger only (admitted=False): the request was never
            # admitted, so the accounting invariant stays intact
            self.router.telemetry.record_drop(rreq.slo.name, "dry_battery",
                                              admitted=False)
            self.tracer.end_request(rreq.rid, self.now, "energy_rejected",
                                    slo=rreq.slo.name)
            admitted = False
        handle = ResponseHandle(self, rreq, work, admitted)
        self._handles[rid] = handle
        return handle

    def _on_token(self, rid: int, tok: int, step: int) -> None:
        h = self._handles.get(rid)
        if h is not None:
            # TTFT stamp: the first token the consumer actually saw,
            # first push wins — honest across reroutes and failovers
            if h._rreq.first_out_s is None:
                h._rreq.first_out_s = self.now
            h._push(tok, step)

    # ------------------------------------------------------------------
    # radiation hardening (data-plane faults + watchdog backstop)
    # ------------------------------------------------------------------
    def _apply_data_plane_fault(self, ev) -> None:
        """Deliver one kv_bitflip / slot_stall / handoff_loss event into
        the target pool's engine (registered as the failover
        controller's ``data_plane`` handler)."""
        f = ev.fault
        eng = self.engines.get(f.pool)
        if eng is None:
            return                 # cost-model pool: nothing to corrupt
        if f.kind == "kv_bitflip" and ev.kind == "degrade":
            eng.arm_bitflip(f.seed)
            self.tracer.event("kv_bitflip", self.now, pool=f.pool,
                              seed=f.seed)
        elif f.kind == "slot_stall":
            if ev.kind == "degrade":
                eng.stall_slot(f.slot)
                self.tracer.event("slot_stall", self.now, pool=f.pool,
                                  slot=f.slot)
            else:                  # transient stall recovers on schedule
                eng.unstall_slot(f.slot)
                self.tracer.event("slot_unstall", self.now, pool=f.pool,
                                  slot=f.slot)
        elif f.kind == "handoff_loss" and ev.kind == "degrade":
            inject = getattr(eng, "inject_handoff_loss", None)
            if inject is not None:   # unified pools have no seam to cut
                inject()
                self.tracer.event("handoff_loss", self.now, pool=f.pool)

    def _watchdog_tick(self) -> None:
        """Fleet-level no-progress backstop: a live admitted handle whose
        token count has not moved for ``watchdog_s`` of virtual time
        counts a watchdog trip (the engine-level watchdog recovers the
        slot; this one makes silent stalls visible even when it cannot)."""
        for rid, h in list(self._watch.items()):
            if rid not in self._handles or self._handles[rid].done:
                self._watch.pop(rid, None)
        for rid, h in self._handles.items():
            if h.done or not h.admitted:
                continue
            n = len(h._tokens)
            rec = self._watch.get(rid)
            if rec is None or rec[0] != n:
                self._watch[rid] = [n, self.now]
            elif self.now - rec[1] > self.watchdog_s:
                self.router.telemetry.watchdog_trips += 1
                self.tracer.event("client_watchdog", self.now, rid=rid,
                                  tokens=n)
                rec[1] = self.now  # re-arm; one trip per stall window

    # ------------------------------------------------------------------
    # clock
    # ------------------------------------------------------------------
    def advance(self, dt: Optional[float] = None) -> None:
        """Move the fleet clock one tick and apply due fault events,
        then run the orbit control loop (bucket, mode, deferral release,
        autoscaling) at the new time."""
        self.now += self.dt if dt is None else dt
        if self.failover is not None:
            self.failover.poll(self.now)
        # SLO burn evaluation runs before the orbit controller so the
        # control loop sees this tick's alert state (a firing page floors
        # the mode at conserve and holds autoscaler scale-down)
        if self.slo_engine is not None:
            self.slo_engine.step(self.now)
        if self.controller is not None:
            self.controller.step(self.now)
        # hardened engines get their budgeted background scrub pass each
        # tick (the decode hot path carries its own fused verify; this
        # covers blocks held while a pool idles between batches).  Scrub
        # findings land outside any executor batch window, so their
        # telemetry deltas are charged here.
        for name, eng in self.engines.items():
            if not getattr(eng, "harden", False):
                continue
            b0 = (eng.bitflips_detected, eng.blocks_quarantined)
            eng.scrub()
            b1 = (eng.bitflips_detected, eng.blocks_quarantined)
            if b1 != b0:
                pc = self.router.telemetry.pools.get(name)
                if pc is not None:
                    pc.bitflips_detected += b1[0] - b0[0]
                    pc.blocks_quarantined += b1[1] - b0[1]
                self.tracer.event("scrub_hit", self.now, pool=name,
                                  found=b1[0] - b0[0])
        if self.watchdog_s is not None:
            self._watchdog_tick()
        self.timeseries.observe(self, self.now)

    def pump(self) -> List[RouterRequest]:
        """Advance every pool at the current time (non-blocking)."""
        completed = self.router.step(self.now)
        if self._retiring:
            self._finish_retirements()
        return completed

    def step(self, dt: Optional[float] = None) -> List[RouterRequest]:
        self.advance(dt)
        return self.pump()

    def drain(self, max_s: float = 600.0,
              dt: Optional[float] = None) -> None:
        """Run until every admitted request and scheduled fault resolves."""
        while self.outstanding or self.pending_faults:
            self.step(dt)
            if self.now > max_s:
                raise RuntimeError(f"fleet failed to drain by t={max_s}s")

    # ------------------------------------------------------------------
    # live fleet mutation (the orbit autoscaler's operations; callers
    # can also drive them directly)
    # ------------------------------------------------------------------
    def attach_controller(self, controller) -> None:
        """Wire an orbit FleetController into the clock and admission
        path (one per client; built by ``OrbitSpec.attach``)."""
        if self.controller is not None:
            raise ValueError("a controller is already attached")
        self.controller = controller

    def attach_slo(self, engine) -> None:
        """Wire an SLO engine into the clock (one per client; built by
        ``SLOSpec.attach``): ``advance`` steps its burn-rate evaluation
        every tick."""
        if self.slo_engine is not None:
            raise ValueError("an SLO engine is already attached")
        self.slo_engine = engine

    def add_pool(self, pool_spec, warm: bool = True) -> None:
        """Grow the fleet live: build the pool a PoolSpec describes and
        join it to the router (frontier refreshes over the widened
        profile set).  Engine pools reuse the model the fleet was built
        with."""
        from repro.serving.spec import build_pool
        pool, engine, ex = build_pool(pool_spec, self._layers,
                                      model=self._model, warm=warm)
        if ex is not None:
            ex.on_token = self._on_token
        self.router.add_pool(pool)
        if ex is not None and ex.prefill_counters is not None:
            # bind back: a reused stage name continues its history
            ex.prefill_counters = self.router.register_stage_pool(
                ex.prefill_pool, ex.prefill_counters)
        if engine is not None:
            self.engines[pool_spec.name] = engine
        self.router.telemetry.pools_added += 1

    def retire_pool(self, name: str) -> None:
        """Shrink the fleet gracefully: the pool stops taking new
        dispatches immediately, finishes everything it holds (no
        in-flight stream is dropped), and is removed once drained — on
        a later ``step()``/``pump()``, not synchronously."""
        from repro.router.pool import PoolState
        pool = self.router.pools[name]       # KeyError -> unknown pool
        live = [p for p in self.router.pools.values()
                if not p.draining and p.state is not PoolState.DEAD]
        if live == [pool]:
            raise ValueError(f"pool {name!r} is the last live pool; a "
                             f"fleet cannot retire itself empty")
        if not pool.draining:
            pool.draining = True
            self._retiring.add(name)

    def set_capacity(self, name: str, capacity: int) -> None:
        """Resize a pool's concurrent-batch capacity in place."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.router.pools[name].capacity = capacity

    def _finish_retirements(self) -> None:
        for name in list(self._retiring):
            pool = self.router.pools.get(name)
            if pool is None:                 # removed out from under us
                self._retiring.discard(name)
                continue
            if pool.load == 0:
                self.router.remove_pool(name)
                self.engines.pop(name, None)
                self._retiring.discard(name)
                self.router.telemetry.pools_retired += 1

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def enable_tracing(self, max_spans: Optional[int] = None) -> None:
        """Turn the flight recorder on: every subsequent submission
        records its span chain (``ResponseHandle.trace()`` reads one
        back; ``repro.obs.export`` serializes them all)."""
        if max_spans is not None:
            self.tracer.max_spans = max_spans
        self.tracer.enabled = True

    @property
    def outstanding(self) -> int:
        deferred = (0 if self.controller is None
                    else self.controller.deferred_count)
        return self.router.outstanding + deferred

    @property
    def pending_faults(self) -> int:
        return 0 if self.failover is None else self.failover.pending_faults

    @property
    def telemetry(self) -> Dict:
        """The fleet-wide snapshot (JSON-serializable)."""
        return self.router.telemetry.snapshot()

    def handle(self, rid: int) -> ResponseHandle:
        return self._handles[rid]
