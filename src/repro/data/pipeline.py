"""Deterministic synthetic data pipelines.

Determinism is the fault-tolerance contract: batch ``step`` is a pure
function of (seed, step, host), so an elastic restart replays the exact
batch sequence with no data-loader state to checkpoint.

Two generators:
  * LM token stream — a simple evolving-ngram language so that tiny
    models actually learn (loss decreases), not just uniform noise;
  * satellite pose task — poses + rendered keypoint-blob images with the
    UrsoNet geometry (the paper's Table I workload; DESIGN.md §9 for why
    synthetic).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


# ---------------------------------------------------------------------------
# LM tokens
# ---------------------------------------------------------------------------
def lm_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
             seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Markov-ish token stream: next token = (a*prev + b) % V with noise."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    b, s, v = shape.global_batch, shape.seq_len, cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (b, 1), 0, v)
    noise = jax.random.bernoulli(k2, 0.1, (b, s - 1))
    rand = jax.random.randint(k3, (b, s - 1), 0, v)

    def step_fn(prev, inp):
        nz, rnd = inp
        nxt = jnp.where(nz, rnd, (prev * 31 + 17) % v)
        return nxt, nxt
    _, rest = jax.lax.scan(step_fn, first[:, 0],
                           (noise.T, rand.T))
    tokens = jnp.concatenate([first, rest.T], axis=1)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels}


def lm_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                   train: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
    return {"tokens": tok, "labels": tok} if train else {"tokens": tok}


# ---------------------------------------------------------------------------
# Satellite pose (UrsoNet synthetic)
# ---------------------------------------------------------------------------
# rigid-body keypoints of a soyuz-ish shape (body frame, meters)
_KEYPOINTS = np.array([
    [0.0, 0.0, 0.0], [1.5, 0.0, 0.0], [-1.5, 0.0, 0.0],
    [0.0, 4.0, 0.0], [0.0, -4.0, 0.0], [0.0, 0.0, 1.2],
    [0.8, 2.0, 0.4], [-0.8, -2.0, -0.4],
], np.float32)
_FOCAL = 600.0
# distinct color signature per keypoint so orientation is recoverable
_KP_COLORS = np.array([
    [1.0, 0.1, 0.1], [0.1, 1.0, 0.1], [0.1, 0.1, 1.0],
    [1.0, 1.0, 0.1], [0.1, 1.0, 1.0], [1.0, 0.1, 1.0],
    [0.9, 0.5, 0.1], [0.4, 0.2, 0.9],
], np.float32)


def _quat_rotate(q: jnp.ndarray, pts: jnp.ndarray) -> jnp.ndarray:
    w, x, y, z = q[..., 0:1], q[..., 1:2], q[..., 2:3], q[..., 3:4]
    r = jnp.stack([
        1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y),
        2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x),
        2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y),
    ], axis=-1).reshape(*q.shape[:-1], 3, 3)
    return jnp.einsum("...ij,kj->...ki", r, pts)


def pose_batch(batch: int, step: int, seed: int = 0,
               image_hw: Tuple[int, int] = (96, 128)
               ) -> Dict[str, jnp.ndarray]:
    """Random poses -> blob-rendered images.  loc in meters (depth 8-24 m),
    orientation unit quaternion — same regime as soyuz_easy."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7919), step)
    kq, kt = jax.random.split(key)
    q = jax.random.normal(kq, (batch, 4))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    q = q * jnp.sign(q[:, :1] + 1e-9)                  # canonical hemisphere
    loc = jnp.stack([
        jax.random.uniform(kt, (batch,), minval=-3.0, maxval=3.0),
        jax.random.uniform(jax.random.fold_in(kt, 1), (batch,), minval=-2.0,
                           maxval=2.0),
        jax.random.uniform(jax.random.fold_in(kt, 2), (batch,), minval=8.0,
                           maxval=24.0),
    ], axis=-1)

    h, w = image_hw
    pts = _quat_rotate(q, jnp.asarray(_KEYPOINTS)) + loc[:, None, :]
    scale = min(h, w) / 960.0
    u = pts[..., 0] / pts[..., 2] * _FOCAL * scale + w / 2
    v = pts[..., 1] / pts[..., 2] * _FOCAL * scale + h / 2
    yy = jnp.arange(h, dtype=jnp.float32)[:, None, None]
    xx = jnp.arange(w, dtype=jnp.float32)[None, :, None]
    sig = 2.0 + 20.0 * scale * 8.0 / pts[..., 2]       # nearer -> bigger blob
    blob = jnp.exp(-((yy - v[:, None, None, :]) ** 2
                     + (xx - u[:, None, None, :]) ** 2)
                   / (2 * sig[:, None, None, :] ** 2))
    chan = jnp.einsum("bhwk,kc->bhwc", blob, jnp.asarray(_KP_COLORS))
    images = jnp.clip(chan, 0.0, 1.0).astype(jnp.float32)
    return {"images": images, "loc": loc, "quat": q}


class HostShardedStream:
    """Per-host view of the global batch (1000-node data ingestion shape):
    host h of H draws rows [h*B/H, (h+1)*B/H) of the deterministic global
    batch — no coordination, no state."""

    def __init__(self, make_batch, global_batch: int, host_id: int,
                 num_hosts: int):
        assert global_batch % num_hosts == 0
        self.make_batch = make_batch
        self.lo = host_id * (global_batch // num_hosts)
        self.hi = (host_id + 1) * (global_batch // num_hosts)

    def __call__(self, step: int):
        full = self.make_batch(step)
        return jax.tree_util.tree_map(lambda a: a[self.lo:self.hi], full)
