"""Pallas TPU kernel: fused RWKV-6 wkv recurrence.

Per head (size N): S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                   y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T).

Same VMEM-resident-state pattern as the selective-scan kernel: the
[N, N] wkv state lives in scratch across the whole sequence (grid seq
dim innermost), avoiding the XLA path's [B, Q, H, N, N] chunk
materialization.  N=64 -> 16 KiB state tile; the per-step work is a
rank-1 update + row-vector product on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

DEFAULT_Q = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                       # [N]

    def step(t, s):
        r_t = r_ref[0, t, 0, :].astype(jnp.float32)        # [N]
        k_t = k_ref[0, t, 0, :].astype(jnp.float32)
        v_t = v_ref[0, t, 0, :].astype(jnp.float32)
        w_t = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                   # [N, N]
        y_t = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, t, 0, :] = y_t.astype(y_ref.dtype)
        return w_t[:, None] * s + kv

    s_ref[...] = jax.lax.fori_loop(0, q, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def wkv_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray, q: int = DEFAULT_Q,
               interpret: bool = False) -> jnp.ndarray:
    """r, k, v, w: [B, S, H, N]; u: [H, N] -> y [B, S, H, N].

    S % q == 0 (ops.py pads with identity decay).
    """
    bsz, s, h, n = r.shape
    assert s % q == 0, (s, q)
    grid = (bsz, h, s // q)
    kernel = functools.partial(_wkv_kernel, q=q)
    spec = pl.BlockSpec((1, q, 1, n), lambda i, j, c: (i, c, j, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, n), lambda i, j, c: (j, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
