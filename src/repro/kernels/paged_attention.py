"""Pallas TPU kernel: paged decode attention (one query token vs a paged
KV cache, walked through the block table — no KV materialization).

The dense-gather decode path (`runtime.paging.gather_kv`) copies every
sequence's KV out of the shared pool into a `[B, max_len, KVp, hd]`
buffer on **every decode step** — O(B * max_len) HBM traffic before a
single MXU cycle runs.  This kernel instead walks each sequence's block
table directly inside the grid: the table and the per-sequence lengths
ride in as scalar-prefetch operands, so the BlockSpec index map can DMA
exactly the pool rows a sequence owns, and `pl.when` skips every block
past the sequence's current length (no DMA'd-but-dead MXU work).
Decode-step traffic drops from O(context) gather+attend to
O(blocks-touched) attend.

Block-table layout contract (shared with ``runtime.paging``):

  * ``k_pool``/``v_pool``: ``[num_rows, P, KVp, hd]`` — ``num_rows``
    fixed-size rows of ``P`` token slots each.  Row ``num_rows - 1`` is
    the *trash row*: never handed out by the allocator, it absorbs
    writes for inactive batch slots and is never read by this kernel.
  * ``block_table``: ``[B, MB] int32`` — row ``b`` lists the pool rows
    of sequence ``b`` in token order; ``-1`` marks an unallocated entry.
    Tokens ``[j*P, (j+1)*P)`` of sequence ``b`` live in pool row
    ``block_table[b, j]`` at slot ``token % P``.
  * ``lengths``: ``[B] int32`` — tokens written per sequence.  Entries
    of ``block_table[b]`` at or past ``ceil(lengths[b] / P)`` are dead:
    the index map clamps them to row 0 (the DMA must target *something*)
    and the kernel body is predicated off, so they contribute nothing.

Grid is ``(B, KVp, MB)`` with the block sweep innermost and
"arbitrary" semantics, so the online-softmax statistics (m, l, acc)
stay VMEM-resident across a sequence's whole table walk — the decode
analogue of `flash_attention.py`'s KV sweep.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page: int, num_blk: int,
                  scale: float):
    b_idx = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = len_ref[b_idx]

    # Skip blocks entirely past this sequence's length: the DMA engine
    # still fetched *a* row (the index map clamps dead table entries to
    # row 0) but neither MXU matmul is issued for it.
    @pl.when(j * page < seq_len)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [gp, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # [P, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [gp, P]
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_blk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           lengths: jnp.ndarray,
                           interpret: bool = False) -> jnp.ndarray:
    """One decode token of paged attention.

    q: [B, KVp, gp, hd]; k_pool/v_pool: [num_rows, P, KVp, hd];
    block_table: [B, MB] int32; lengths: [B] int32 -> [B, KVp, gp, hd].
    """
    b, kvp, gp, hd = q.shape
    page = k_pool.shape[1]
    mb = block_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_paged_kernel, page=page, num_blk=mb,
                               scale=scale)

    def q_map(i, h, j, tbl, lens):
        return (i, h, 0, 0)

    def kv_map(i, h, j, tbl, lens):
        # dead entries (-1) clamp to row 0; the body is predicated off
        return (jnp.maximum(tbl[i, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvp, mb),
        in_specs=[
            pl.BlockSpec((1, 1, gp, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, hd), q_map),
        scratch_shapes=[
            # VMEM-resident online-softmax statistics across the walk
            pltpu.VMEM((gp, 1), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
            pltpu.VMEM((gp, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvp, gp, hd), q.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
