"""Pallas TPU kernel: fused row-wise absmax int8 quantization.

One VMEM pass computes the per-row absmax and writes the quantized int8
rows plus fp32 scales — fusing what XLA would schedule as a reduce +
HBM round-trip + elementwise pass.  Feeds the int8 matmul kernel's
activation operand (dynamic per-tensor/row activation quantization of the
DPU path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

QMAX = 127.0
DEFAULT_BM = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / QMAX
    q_ref[...] = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def rowwise_quant_pallas(x: jnp.ndarray, bm: int = DEFAULT_BM,
                         interpret: bool = False):
    """x: [M, K] float -> (q [M, K] int8, scale [M, 1] f32).

    M must be a multiple of bm; the full K extent of a row block lives in
    VMEM (ops.py asserts K*bm fits).
    """
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, k), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
