"""Public jit'd wrappers for the Pallas kernels.

Handles shape alignment (padding to block multiples), GQA kv expansion,
and backend selection: on TPU the compiled kernels run natively; on CPU
(this container) ``interpret=True`` executes the kernel bodies in Python
for correctness validation.  ``REPRO_FORCE_INTERPRET=0`` disables the
override on real hardware.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.int8_matmul import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN,
                                       int8_matmul_pallas)
from repro.kernels.quant import rowwise_quant_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels.wkv import wkv_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def int8_matmul(x: jnp.ndarray, w: jnp.ndarray,
                bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                bn: int = DEFAULT_BN) -> jnp.ndarray:
    """x: [M, K] int8, w: [K, N] int8 -> [M, N] int32 (padded + unpadded)."""
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    out = int8_matmul_pallas(xp, wp, bm=bm, bk=bk, bn=bn,
                             interpret=_interpret())
    return out[:m, :n]


def rowwise_quant(x: jnp.ndarray, bm: int = 256):
    """x: [M, K] float -> (q int8 [M, K], scale f32 [M, 1])."""
    m, k = x.shape
    bm = min(bm, max(8, m))
    xp = _pad_to(x, (bm, 1))
    q, s = rowwise_quant_pallas(xp, bm=bm, interpret=_interpret())
    return q[:m], s[:m]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    bq: int = 256, bk: int = 256) -> jnp.ndarray:
    """Causal attention.  q: [B, S, H, D]; k, v: [B, S, KV, D] (GQA ok)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bq = min(bq, s)
    bk_ = min(bk, s)
    if s % bq or s % bk_:
        raise ValueError(f"seq {s} must divide block sizes ({bq},{bk_})")
    out = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bk=bk_,
                                 interpret=_interpret())
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def selective_scan(x, dt, b, c, a, d, bd: int = 512, q: int = 256):
    """Fused Mamba selective scan.  x, dt: [B,S,D]; b, c: [B,S,N];
    a: [D,N]; d: [D] -> y [B,S,D] (pads D and S to block multiples)."""
    bsz, s, dim = x.shape
    bd = min(bd, dim)
    q = min(q, s)
    pd = (-dim) % bd
    ps = (-s) % q
    if pd or ps:
        padx = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, pd)))
        padn = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, 0)))
        x, dt = padx(x), padx(dt)
        b, c = padn(b), padn(c)
        a = jnp.pad(a, ((0, pd), (0, 0)))
        d = jnp.pad(d, (0, pd))
    out = selective_scan_pallas(x, dt, b, c, a, d, bd=bd, q=q,
                                interpret=_interpret())
    return out[:, :s, :dim]


def wkv(r, k, v, w, u, q: int = 128):
    """Fused RWKV-6 wkv.  r,k,v,w: [B,S,H,N]; u: [H,N] -> y [B,S,H,N]."""
    bsz, s, h, n = r.shape
    q = min(q, s)
    ps = (-s) % q
    if ps:
        padz = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, 0), (0, 0)))
        r, k, v = padz(r), padz(k), padz(v)
        w = jnp.pad(w, ((0, 0), (0, ps), (0, 0), (0, 0)),
                    constant_values=1.0)           # identity decay on pad
    out = wkv_pallas(r, k, v, w, u, q=q, interpret=_interpret())
    return out[:, :s]


# re-export oracles for tests/benchmarks
int8_matmul_ref = ref.int8_matmul_ref
rowwise_quant_ref = ref.rowwise_quant_ref
flash_attention_ref = ref.flash_attention_ref
selective_scan_ref = ref.selective_scan_ref
wkv_ref = ref.wkv_ref
