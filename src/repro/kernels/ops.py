"""Public jit'd wrappers for the Pallas kernels.

Handles shape alignment (padding to block multiples), GQA kv expansion,
and backend selection: on TPU the compiled kernels run natively; on CPU
(this container) ``interpret=True`` executes the kernel bodies in Python
for correctness validation.  ``REPRO_FORCE_INTERPRET=0`` disables the
override on real hardware.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.int8_matmul import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN,
                                       int8_matmul_pallas)
from repro.kernels.quant import rowwise_quant_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels.wkv import wkv_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def int8_matmul(x: jnp.ndarray, w: jnp.ndarray,
                bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                bn: int = DEFAULT_BN) -> jnp.ndarray:
    """x: [M, K] int8, w: [K, N] int8 -> [M, N] int32 (padded + unpadded)."""
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    out = int8_matmul_pallas(xp, wp, bm=bm, bk=bk, bn=bn,
                             interpret=_interpret())
    return out[:m, :n]


def rowwise_quant(x: jnp.ndarray, bm: int = 256):
    """x: [M, K] float -> (q int8 [M, K], scale f32 [M, 1])."""
    m, k = x.shape
    bm = min(bm, max(8, m))
    xp = _pad_to(x, (bm, 1))
    q, s = rowwise_quant_pallas(xp, bm=bm, interpret=_interpret())
    return q[:m], s[:m]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    bq: int = 256, bk: int = 256) -> jnp.ndarray:
    """Causal attention.  q: [B, S, H, D]; k, v: [B, S, KV, D] (GQA ok)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bq = min(bq, s)
    bk_ = min(bk, s)
    if s % bq or s % bk_:
        raise ValueError(f"seq {s} must divide block sizes ({bq},{bk_})")
    out = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bk=bk_,
                                 interpret=_interpret())
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@jax.jit
def paged_attention_xla(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_table: jnp.ndarray,
                        lengths: jnp.ndarray) -> jnp.ndarray:
    """XLA-native analogue of the Pallas paged-decode kernel: a scan over
    block-table columns with online softmax — one block per sequence in
    flight at a time, never the materialized [B, max_len] KV of
    ``gather_kv``.  This is the fast path on non-TPU backends, where the
    Pallas kernel would run under the (slow) interpreter."""
    b, kvp, gp, hd = q.shape
    page = k_pool.shape[1]
    mb = block_table.shape[1]
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))

    def step(carry, j):
        m, l, acc = carry
        rows = jnp.maximum(block_table[:, j], 0)
        k = k_pool[rows].astype(jnp.float32)          # [B, P, KVp, hd]
        v = v_pool[rows].astype(jnp.float32)
        s = jnp.einsum("bkgd,bpkd->bkgp", qf, k)
        pos = j * page + jnp.arange(page)
        mask = pos[None, :] < lengths[:, None]        # [B, P]
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # zero masked contributions explicitly: for a fully-masked row
        # (lengths == 0) m_new stays -1e30 and exp(s - m_new) would be 1,
        # leaking clamped row-0 V; the Pallas kernel returns exactly 0
        # there (its body never runs) and this path must match
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgp,bpkd->bkgd", p, v)
        return (m_new, l, acc), None

    init = (jnp.full((b, kvp, gp), -1e30, jnp.float32),
            jnp.zeros((b, kvp, gp), jnp.float32),
            jnp.zeros((b, kvp, gp, hd), jnp.float32))
    # unrolled: MB is small (max_len / P) and per-iteration scan overhead
    # would dominate the tiny per-block einsums on CPU
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(mb), unroll=True)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray,
                    lengths: jnp.ndarray) -> jnp.ndarray:
    """One-token paged decode attention — walks the block table, never
    materializing a sequence's full KV.

    q: [B, KVp, gp, hd] (one query token per sequence, grouped-query
    layout); k_pool/v_pool: [num_rows, P, KVp, hd] shared block pools;
    block_table: [B, MB] int32; lengths: [B] int32.  Returns
    [B, KVp, gp, hd].  See kernels/paged_attention.py for the layout
    contract.  No shape padding here: head_layout already aligns KVp/gp
    and the pool's P is the engine's block size.

    Backend selection differs from the other wrappers: on TPU the Pallas
    kernel runs compiled; elsewhere the serving path takes the XLA
    block-walk analogue at full native speed instead of the Pallas
    interpreter (which emulates the grid serially — fine for the
    equivalence tests that pin kernel-vs-reference numerics, hopeless
    for a throughput benchmark).  ``REPRO_PAGED_PALLAS=1`` forces the
    interpreted kernel for debugging.
    """
    if not _interpret() or os.environ.get("REPRO_PAGED_PALLAS") == "1":
        return paged_attention_pallas(q, k_pool, v_pool, block_table,
                                      lengths, interpret=_interpret())
    return paged_attention_xla(q, k_pool, v_pool, block_table, lengths)


@jax.jit
def paged_chunk_attention_xla(q: jnp.ndarray, k_pool: jnp.ndarray,
                              v_pool: jnp.ndarray, table_row: jnp.ndarray,
                              qpos: jnp.ndarray) -> jnp.ndarray:
    """Chunk-prefill attention for ONE sequence against its paged KV.

    The chunked-paged-prefill companion to :func:`paged_attention_xla`:
    a C-token query block (one prefill chunk, already pasted into the
    pool by ``paging.write_prefill_chunk``) attends causally to every
    earlier position of its own sequence — the paged prefix written by
    previous chunks plus the in-chunk lower triangle — walking the
    sequence's block-table row with an online softmax, one block in
    flight at a time.

    q: [C, KVp, gp, hd]; k_pool/v_pool: [num_rows, P, KVp, hd];
    table_row: [MB] int32 (-1 = unallocated); qpos: [C] int32 absolute
    positions of the chunk.  Returns [C, KVp, gp, hd].  Blocks past the
    chunk (decode-budget rows, dead entries) fall to the causal mask:
    their positions exceed every query position.
    """
    c, kvp, gp, hd = q.shape
    page = k_pool.shape[1]
    mb = table_row.shape[0]
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))

    def step(carry, j):
        m, l, acc = carry
        row = jnp.maximum(table_row[j], 0)
        k = k_pool[row].astype(jnp.float32)           # [P, KVp, hd]
        v = v_pool[row].astype(jnp.float32)
        s = jnp.einsum("ckgd,pkd->kgcp", qf, k)
        pos = j * page + jnp.arange(page)
        mask = (pos[None, :] <= qpos[:, None]) & (table_row[j] >= 0)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("kgcp,pkd->kgcd", p, v)
        return (m_new, l, acc), None

    init = (jnp.full((kvp, gp, c), -1e30, jnp.float32),
            jnp.zeros((kvp, gp, c), jnp.float32),
            jnp.zeros((kvp, gp, c, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(mb), unroll=True)
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # [KVp, gp, C, hd]
    return out.transpose(2, 0, 1, 3).astype(q.dtype)


def paged_chunk_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                          v_pool: jnp.ndarray, table_row: jnp.ndarray,
                          qpos: jnp.ndarray) -> jnp.ndarray:
    """Backend front door for chunk-prefill paged attention (see
    :func:`paged_chunk_attention_xla`).  The block-walk runs as native
    XLA everywhere today; a Pallas grid over (q-block, kv-block) with
    the same scalar-prefetch table walk as the decode kernel is the
    drop-in TPU upgrade and slots in here."""
    return paged_chunk_attention_xla(q, k_pool, v_pool, table_row, qpos)


def selective_scan(x, dt, b, c, a, d, bd: int = 512, q: int = 256):
    """Fused Mamba selective scan.  x, dt: [B,S,D]; b, c: [B,S,N];
    a: [D,N]; d: [D] -> y [B,S,D] (pads D and S to block multiples)."""
    bsz, s, dim = x.shape
    bd = min(bd, dim)
    q = min(q, s)
    pd = (-dim) % bd
    ps = (-s) % q
    if pd or ps:
        padx = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, pd)))
        padn = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, 0)))
        x, dt = padx(x), padx(dt)
        b, c = padn(b), padn(c)
        a = jnp.pad(a, ((0, pd), (0, 0)))
        d = jnp.pad(d, (0, pd))
    out = selective_scan_pallas(x, dt, b, c, a, d, bd=bd, q=q,
                                interpret=_interpret())
    return out[:, :s, :dim]


def wkv(r, k, v, w, u, q: int = 128):
    """Fused RWKV-6 wkv.  r,k,v,w: [B,S,H,N]; u: [H,N] -> y [B,S,H,N]."""
    bsz, s, h, n = r.shape
    q = min(q, s)
    ps = (-s) % q
    if ps:
        padz = lambda t_: jnp.pad(t_, ((0, 0), (0, ps), (0, 0), (0, 0)))
        r, k, v = padz(r), padz(k), padz(v)
        w = jnp.pad(w, ((0, 0), (0, ps), (0, 0), (0, 0)),
                    constant_values=1.0)           # identity decay on pad
    out = wkv_pallas(r, k, v, w, u, q=q, interpret=_interpret())
    return out[:, :s]


# re-export oracles for tests/benchmarks
int8_matmul_ref = ref.int8_matmul_ref
rowwise_quant_ref = ref.rowwise_quant_ref
flash_attention_ref = ref.flash_attention_ref
selective_scan_ref = ref.selective_scan_ref
wkv_ref = ref.wkv_ref
