"""Pallas TPU kernel: blocked int8 x int8 -> int32 matmul (the DPU path).

The DPU's deep-pipelined INT8 MAC array maps onto the MXU's int8 mode
(2x bf16 throughput on v5e).  Tiling: [bm, bk] x [bk, bn] blocks staged
through VMEM; the K grid dimension is innermost ("arbitrary") so each
[bm, bn] output tile stays resident in VMEM across the K loop —
the VMEM-as-accumulator role the DPU assigns to its on-chip activation
buffers.

Block defaults (128, 256, 128) keep the working set at
128*256 + 256*128 + 128*128*4 = 128 KiB << 16 MiB VMEM and all matmul
dims MXU-aligned (multiples of 128 / int8 lane packing of 32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


DEFAULT_BM = 128
DEFAULT_BK = 256
DEFAULT_BN = 128


def _int8_matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def int8_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray,
                       bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                       bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jnp.ndarray:
    """x: [M, K] int8, w: [K, N] int8 -> [M, N] int32.

    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _int8_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
