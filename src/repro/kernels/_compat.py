"""Version compat for Pallas TPU symbols.

``pltpu.CompilerParams`` was ``pltpu.TPUCompilerParams`` before the
rename; the container's jax only has the old name.  Every kernel module
imports the resolved class from here so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
assert CompilerParams is not None, "no Pallas TPU CompilerParams class"
