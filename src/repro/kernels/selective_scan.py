"""Pallas TPU kernel: fused Mamba selective scan.

Motivated directly by the §Perf finding on jamba train_4k: the XLA
chunked-associative-scan path materializes [B, Q, d_inner, N] state
tensors for the backward pass (1.38 TB/dev transient at full scale).
The fused kernel keeps the running state h [bd, N] in VMEM scratch and
streams the sequence through it — the TPU analogue of the CUDA
selective-scan kernel's shared-memory recurrence (DESIGN.md §2):

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
    y_t = h_t . C_t + D * x_t

Grid: (batch, d_inner blocks, seq chunks) with the seq dimension
innermost ("arbitrary"), so each [bd, N] state tile stays resident in
VMEM across its whole sequence.  Inside a chunk the recurrence runs as a
``fori_loop`` over positions — [bd, N] elementwise VPU work per step.

Block sizing: bd=512, N=16 -> h tile 32 KiB; x/dt chunks [Q=256, bd]
bf16 = 256 KiB; B/C chunks [Q, N] tiny.  Working set ~1 MiB << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

DEFAULT_BD = 512
DEFAULT_Q = 256


def _selective_scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
                           y_ref, h_ref, *, q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)            # [bd, N]
    dskip = d_ref[...].astype(jnp.float32)        # [bd]

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)        # [bd]
        x_t = x_ref[0, t, :].astype(jnp.float32)          # [bd]
        b_t = b_ref[0, t, :].astype(jnp.float32)          # [N]
        c_t = c_ref[0, t, :].astype(jnp.float32)          # [N]
        a_bar = jnp.exp(dt_t[:, None] * a)                # [bd, N]
        h = a_bar * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + dskip * x_t
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, q, step, h_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("bd", "q", "interpret"))
def selective_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, b: jnp.ndarray,
                          c: jnp.ndarray, a: jnp.ndarray, d: jnp.ndarray,
                          bd: int = DEFAULT_BD, q: int = DEFAULT_Q,
                          interpret: bool = False) -> jnp.ndarray:
    """x, dt: [B, S, D]; b, c: [B, S, N]; a: [D, N]; d: [D] -> y [B, S, D].

    D % bd == 0 and S % q == 0 (ops.py pads).
    """
    bsz, s, dim = x.shape
    n = b.shape[-1]
    assert dim % bd == 0 and s % q == 0, (dim, bd, s, q)
    grid = (bsz, dim // bd, s // q)
    kernel = functools.partial(_selective_scan_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, bd), lambda i, j, k: (i, k, j)),   # x
            pl.BlockSpec((1, q, bd), lambda i, j, k: (i, k, j)),   # dt
            pl.BlockSpec((1, q, n), lambda i, j, k: (i, k, 0)),    # B
            pl.BlockSpec((1, q, n), lambda i, j, k: (i, k, 0)),    # C
            pl.BlockSpec((bd, n), lambda i, j, k: (j, 0)),         # A
            pl.BlockSpec((bd,), lambda i, j, k: (j,)),             # D skip
        ],
        out_specs=pl.BlockSpec((1, q, bd), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, dim), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a, d)
