"""Pallas TPU kernel: causal flash attention forward (online softmax).

Blocked [bq x bk] score tiles with running (m, l, acc) statistics held in
VMEM scratch; the KV grid dimension is innermost so statistics stay
resident across the KV sweep.  This is the TPU-native replacement for the
prefill hot loop — VMEM tiles instead of SRAM tiles, MXU matmuls for both
QK^T and PV.

Layout: q, k, v are [BH, S, D] (batch*heads folded; GQA callers repeat kv
in the ops wrapper).  Causal masking is positional, so block pairs with
q_block < kv_block contribute nothing (masked to -inf; the `l` correction
keeps the math exact).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, scale: float, num_kv: int):
    q_idx = pl.program_id(1)
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causality: a tile whose first k position is past the tile's last q
    # position is fully masked — skip both MXU matmuls for the whole
    # upper-triangular half of the (q, kv) grid (~2x at long S).
    @pl.when(kv_idx * bk <= q_idx * bq + bq - 1)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale      # [bq, d]
        k = k_ref[0].astype(jnp.float32)              # [bk, d]
        v = v_ref[0].astype(jnp.float32)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        q_pos = q_idx * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_idx == num_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jnp.ndarray:
    """q, k, v: [BH, S, D] -> [BH, S, D], causal."""
    bh, s, d = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    num_kv = s // bk
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                               num_kv=num_kv)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq, num_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            # VMEM running statistics for the online softmax
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


