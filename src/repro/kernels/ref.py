"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

QMAX = 127.0


def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [M, K] int8, w: [K, N] int8 -> [M, N] int32."""
    return jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def rowwise_quant_ref(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / QMAX
    q = jnp.clip(jnp.round(xf / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                        v: jnp.ndarray) -> jnp.ndarray:
    """Causal softmax attention.  q, k, v: [BH, S, D]."""
    s = q.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(x, dt, b, c, a, d):
    """Naive per-token recurrence.  x, dt: [B,S,D]; b, c: [B,S,N];
    a: [D,N]; d: [D] -> y [B,S,D]."""
    bsz, s, dim = x.shape
    n = b.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a_bar = jnp.exp(dt_t[:, :, None] * a)            # [B,D,N]
        h = a_bar * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + d * x_t
        return h, y
    h0 = jnp.zeros((bsz, dim, n), jnp.float32)
    xs = (x.astype(jnp.float32).swapaxes(0, 1),
          dt.astype(jnp.float32).swapaxes(0, 1),
          b.astype(jnp.float32).swapaxes(0, 1),
          c.astype(jnp.float32).swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def wkv_ref(r, k, v, w, u):
    """Naive RWKV-6 recurrence.  r,k,v,w: [B,S,H,N]; u: [H,N]."""
    bsz, s, h, n = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                        # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B,H,N,N]
        y = jnp.einsum("bhn,bhnm->bhm", r_t,
                       state + u[None, :, :, None] * kv)
        return w_t[..., None] * state + kv, y
    xs = tuple(t_.astype(jnp.float32).swapaxes(0, 1) for t_ in (r, k, v, w))
    s0 = jnp.zeros((bsz, h, n, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype)
