"""PartitionSpec rules: how every parameter, batch and cache shards over
the production mesh.

Axes: ``pod`` (cross-pod DP / MPAI stage axis), ``data`` (DP; also the
FSDP shard axis for archs flagged ``fsdp=True``), ``model`` (TP/EP).

Rules are name-based on the *trailing* dims of each leaf; extra leading
dims (the scan-stack layer dim, MoE expert dims handled explicitly) pad
with None.  This keeps one table covering every architecture family.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig

MODEL = "model"


def _fsdp_axis(cfg: ModelConfig, mesh_cfg: Optional[MeshConfig] = None):
    if not cfg.fsdp:
        return None
    if mesh_cfg is not None and "pod" in mesh_cfg.axes:
        return ("pod", "data")        # ZeRO-3 over every DP axis
    return "data"


# trailing-dim spec tables -------------------------------------------------
def _trailing_spec(name: str, ndim: int, cfg: ModelConfig,
                   mesh_cfg: Optional[MeshConfig] = None):
    f = _fsdp_axis(cfg, mesh_cfg)
    # MoE expert tensors (expert dim sharded over model = EP)
    if name in ("w_in", "w_gate") and ndim == 3:
        return (MODEL, f, None)
    if name == "w_out" and ndim == 3:
        return (MODEL, None, f)
    table = {
        # attention
        "wq": (f, MODEL), "wk": (f, MODEL), "wv": (f, MODEL),
        "wo": (MODEL, f),
        # dense MLP
        "w_in": (f, MODEL), "w_gate": (f, MODEL), "w_out": (MODEL, f),
        # router (tiny, accuracy-critical: replicated)
        "router": (None, None),
        # mamba
        "in_proj": (f, MODEL), "out_proj": (MODEL, f),
        "x_proj": (MODEL, None), "dt_proj": (None, MODEL),
        "conv_w": (None, MODEL), "conv_b": (MODEL,),
        "dt_bias": (MODEL,), "A_log": (MODEL, None), "D": (MODEL,),
        # rwkv time-mix
        "w_r": (f, MODEL), "w_k": (f, MODEL), "w_v": (f, MODEL),
        "w_g": (f, MODEL), "w_o": (MODEL, f),
        "w0": (MODEL,), "wB": (None, MODEL), "u": (MODEL,),
        "gn_scale": (MODEL,), "gn_bias": (MODEL,),
        # rwkv channel-mix
        "w_kc": (f, MODEL), "w_vc": (MODEL, f), "w_rc": (f, MODEL),
    }
    return table.get(name)


def _leaf_name(path) -> str:
    """Last meaningful name: skips QTensor field entries (values/scale are
    SequenceKey/GetAttrKey under the named weight)."""
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey) and \
                entry.name not in ("values", "scale"):
            return entry.name
    return ""


def _dp_axes(mesh_cfg: Optional[MeshConfig]):
    axes = mesh_cfg.axes if mesh_cfg is not None else ("data", "model")
    return tuple(a for a in ("pod", "data", "model") if a in axes)


def _sanitize(spec_tuple, shape, mesh_cfg: Optional[MeshConfig]):
    """None out axes on size-1 dims (QTensor scales) and assert the rest."""
    out = []
    for dim, ax in zip(shape, spec_tuple):
        out.append(None if (ax is not None and dim == 1) else ax)
    return tuple(out)


def param_specs(cfg: ModelConfig, params_shape,
                mesh_cfg: Optional[MeshConfig] = None) -> object:
    """PartitionSpec pytree matching ``params_shape`` (a tree of arrays or
    ShapeDtypeStructs).  Handles QTensor leaves (values share the float
    weight's spec; broadcast scale dims replicate)."""
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape)) if mesh_cfg else \
        {"data": 16, "model": 16}

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        top = str(path[0].key) if isinstance(path[0], jax.tree_util.DictKey) else ""
        in_stack = top == "layers"
        trail_nd = nd - (1 if in_stack else 0)
        if cfg.sharding_mode == "fsdp" and cfg.moe is None:
            comb = _dp_axes(mesh_cfg)
            total = 1
            for a in comb:
                total *= sizes.get(a, 1)
            if top in ("embed", "lm_head"):
                if leaf.shape[0] % total == 0:
                    return P(comb, None)
                return P(MODEL, None)      # vocab not mesh-divisible
            if trail_nd >= 2:
                # shard the first trailing dim divisible by the full mesh
                dims = leaf.shape[-trail_nd:]
                tsp = [None] * trail_nd
                for i, dsz in enumerate(dims):
                    if dsz % total == 0:
                        tsp[i] = comb
                        break
                tsp = _sanitize(tuple(tsp), dims, mesh_cfg)
                return P(*(((None,) if in_stack else ()) + tuple(tsp)))
            return P()
        if top in ("embed", "lm_head"):
            # vocab-sharded only: FSDP-sharding the table's d_model dim makes
            # the token gather unpartitionable (SPMD full-remat; observed on
            # llama3-405b) — the table is small relative to the blocks anyway
            return P(MODEL, None)
        tspec = _trailing_spec(name, trail_nd, cfg, mesh_cfg)
        if tspec is None:
            return P()                                 # norms, scalars, mus
        assert len(tspec) == trail_nd, (name, leaf.shape, tspec)
        tspec = _sanitize(tuple(tspec), leaf.shape[-trail_nd:], mesh_cfg)
        return P(*(((None,) if in_stack else ()) + tuple(tspec)))
    return jax.tree_util.tree_map_with_path(spec, params_shape)


# ---------------------------------------------------------------------------
# Data / cache / logits specs
# ---------------------------------------------------------------------------
def batch_axes(global_batch: int, mesh_cfg: MeshConfig,
               include_model: bool = False):
    """Largest prefix of the DP axes that divides the batch."""
    pool = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in pool if a in mesh_cfg.axes]
    sizes = {a: mesh_cfg.shape[mesh_cfg.axes.index(a)] for a in axes}
    chosen, div = [], 1
    for a in axes:
        if global_batch % (div * sizes[a]) == 0:
            chosen.append(a)
            div *= sizes[a]
    return tuple(chosen) or None


def data_specs(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig):
    b_ax = batch_axes(shape.global_batch, mesh_cfg,
                      include_model=cfg.sharding_mode == "fsdp")
    tok = P(b_ax, None)
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = tok
    if cfg.frontend != "none" and shape.kind in ("train", "prefill"):
        out["frontend_embeds"] = P(b_ax, None, None)
    return out


def cache_specs(cfg: ModelConfig, cache_shape, shape: ShapeConfig,
                mesh_cfg: MeshConfig):
    """Spec tree matching the stacked decode cache."""
    b_ax = batch_axes(shape.global_batch, mesh_cfg)

    def spec(path, leaf):
        nd = len(leaf.shape)
        name = _leaf_name(path)
        if nd <= 1:                                   # pos scalars [L]
            return P()
        if name in ("k", "v"):                        # [L, B, T, KVp, hd]
            return P(None, b_ax, None, MODEL, None)
        if name in ("k_scale", "v_scale"):            # [L, B, T, KVp, 1]
            return P(None, b_ax, None, MODEL, None)
        if name == "h":                               # mamba [L, B, di, N]
            return P(None, b_ax, MODEL, None)
        if name == "conv":                            # [L, B, k, di]
            return P(None, b_ax, None, MODEL)
        if name == "s":                               # rwkv [L, B, Hp, N, N]
            return P(None, b_ax, MODEL, None, None)
        if name in ("x_tmix", "x_cmix"):              # [L, B, D]
            return P(None, b_ax, None)
        return P(*((None,) * nd))
    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def logits_spec(shape: ShapeConfig, mesh_cfg: MeshConfig):
    return P(batch_axes(shape.global_batch, mesh_cfg), None, MODEL)


def make_shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
