"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Layer pattern (period 8): one attention layer per 8 (the 1:7 ratio), MoE
MLP on every other layer.  No positional embeddings (rope_theta=0) — the
Mamba layers carry position.  long_500k runs: decode against a 500k
context is O(1)-state for the 28 Mamba layers and linear-per-token for
the 4 attention layers' KV caches (~8.6 GB total in bf16 at KVp=16,
sharded 16-way -> 540 MB/chip).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    rope_theta=0.0,
    mixer="hybrid",
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
    remat=True,
    fsdp=True,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    rope_theta=0.0,
    mixer="hybrid",
    attn_every=8,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, every=2),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
    remat=False,
)
