"""LLaVA-NeXT (Mistral-7B backbone) — VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone only (per the assignment): the anyres vision tower is a STUB
delivering precomputed patch embeddings (576 = 24x24 base grid) that are
prepended to the token sequence.  Mistral-7B geometry: 32L, GQA kv=8,
SwiGLU 14336, theta 1e6.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=576,
    remat=True,
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=8,
    remat=False,
)
