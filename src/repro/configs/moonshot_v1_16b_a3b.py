"""Moonlight-16B-A3B (Moonshot) — MoE, 64 experts top-6 + shared experts
[hf:moonshotai/Moonlight-16B-A3B].

48L, d=2048, MHA (kv=16), per-expert SwiGLU hidden 1408, 2 shared experts
(always-on, 2816 combined hidden), vocab 163840.
"""
from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, every=1,
                  shared_d_ff=2816),
    remat=True,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, every=1,
                  shared_d_ff=128),
    remat=False,
)
