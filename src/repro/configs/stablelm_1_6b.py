"""StableLM-2 1.6B — dense, MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b].

24L, d=2048, 32 heads, SwiGLU 5632, vocab 100352.  (Deviation: upstream
uses LayerNorm + partial rope; we use the framework's RMSNorm + full rope
— noted in DESIGN.md §9.)
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_theta=10000.0,
    remat=True,
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    remat=False,
)
