"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import (LONG_500K, DECODE_32K, PREFILL_32K, TRAIN_4K,
                                MeshConfig, ModelConfig, MoEConfig, MULTI_POD,
                                ShapeConfig, SHAPES, SINGLE_POD, SMOKE_MESH,
                                TrainConfig)

from repro.configs import (jamba_v0_1_52b, llama3_405b,
                           llava_next_mistral_7b, moonshot_v1_16b_a3b,
                           musicgen_medium, olmoe_1b_7b, qwen3_14b,
                           rwkv6_3b, stablelm_1_6b, starcoder2_15b)

_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "musicgen-medium": musicgen_medium,
    "qwen3-14b": qwen3_14b,
    "stablelm-1.6b": stablelm_1_6b,
    "llama3-405b": llama3_405b,
    "starcoder2-15b": starcoder2_15b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "rwkv6-3b": rwkv6_3b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.FULL


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  long_500k only runs for archs with
    sub-quadratic decode state (the assignment's skip rule)."""
    out = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            skip = (shape.name == "long_500k"
                    and not cfg.supports_long_context())
            if include_skipped or not skip:
                out.append((name, shape.name, skip))
    return out
