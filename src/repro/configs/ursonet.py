"""UrsoNet — the paper's own workload (satellite pose estimation,
Table I).  CNN config, not part of the 10-arch LM pool; used by the
paper-reproduction benchmarks and examples."""
from repro.models.cnn import UrsoNetConfig

FULL = UrsoNetConfig(name="ursonet", image_hw=(192, 256),
                     widths=(32, 64, 128, 256), blocks_per_stage=2,
                     fc_dim=256)

SMOKE = UrsoNetConfig(name="ursonet-smoke", image_hw=(96, 128),
                      widths=(8, 16), blocks_per_stage=1, fc_dim=32)
