"""Configuration dataclasses for the MPAI framework.

Every assigned architecture is described by a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` entries; meshes are
:class:`MeshConfig`.  Configs are frozen dataclasses so they hash and can be
used as jit static arguments.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Mixture-of-Experts
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int                  # hidden dim of each expert MLP
    capacity_factor: float = 1.25
    every: int = 1                    # a MoE MLP every `every` layers (else dense)
    router_dtype: str = "float32"     # routers are accuracy-critical: never quantized
    shared_d_ff: int = 0              # optional shared (always-on) expert hidden dim


# ---------------------------------------------------------------------------
# Mamba / RWKV mixer hyper-parameters
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, math.ceil(d_model / 16))


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64              # rank of the data-dependent decay LoRA
    mix_lora: int = 32                # rank of the token-shift mixing LoRA


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                    # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    act: str = "silu"                 # silu (SwiGLU) | gelu (plain GeLU MLP)
    glu: bool = True
    tie_embeddings: bool = False
    # mixer layout --------------------------------------------------------
    mixer: str = "attention"          # attention | mamba | rwkv6 | hybrid
    attn_every: int = 1               # hybrid: one attention layer per `attn_every`
    sliding_window: int = 0           # 0 = full causal; >0 = windowed attention
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # modality frontend (STUB: input_specs deliver precomputed embeddings) --
    frontend: str = "none"            # none | vision | audio
    frontend_tokens: int = 0          # prepended embedding tokens per sample
    # memory knobs ---------------------------------------------------------
    remat: bool = True
    remat_policy: str = "none"        # none (recompute all) | dots (save dot
    #   outputs: remat recompute skips matmuls AND their TP all-reduces —
    #   trades HBM for collective+compute, §Perf)
    remat_group: int = 0              # >0: sqrt remat — checkpoint groups of
    #   this many super-blocks (outer) on top of per-block remat (inner);
    #   memory ~ (n/G + G) boundaries instead of n, compute ~10ND vs 8ND
    fsdp: bool = False                # 2D (data x model) parameter sharding
    sharding_mode: str = "tp"         # tp | fsdp (pure ZeRO-3, no TP —
    #   beats TP on collective bytes for small dense models, §Perf)
    grad_accum: int = 1               # microbatches per train step
    scan_layers: bool = True          # False: unroll ALL scans (cost probes)
    scan_chunk: int = 0               # SSM/linear-mixer chunk (0 = default)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (per-token-per-head
    #   absmax scales; halves the decode-dominant cache traffic — §Perf)

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    # ------------------------------------------------------------------
    # Head padding for tensor parallelism (documented in DESIGN.md §5):
    # head counts that do not divide the TP degree are zero-padded up to
    # the next multiple; kv heads are replicated to lcm(kv, tp).
    # ------------------------------------------------------------------
    def padded_heads(self, tp: int) -> int:
        h = self.num_heads
        return h if h % max(tp, 1) == 0 else ((h + tp - 1) // tp) * tp

    def padded_kv_heads(self, tp: int) -> int:
        kv = self.num_kv_heads
        if tp <= 1 or kv % tp == 0:
            return kv
        return math.lcm(kv, tp)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim()
        n = v * d                                            # embedding
        if not self.tie_embeddings:
            n += v * d                                       # lm head
        for i in range(L):
            kind = self.layer_mixer(i)
            if kind == "attention":
                n += d * (self.num_heads * hd) * 2           # q, o
                n += d * (self.num_kv_heads * hd) * 2        # k, v
            elif kind == "mamba":
                mc = self.mamba or MambaConfig()
                di = mc.expand * d
                n += d * 2 * di + di * d                     # in/out proj
                n += di * mc.d_conv                          # conv
                n += di * (mc.resolved_dt_rank(d) + 2 * mc.d_state)
                n += mc.resolved_dt_rank(d) * di + di * mc.d_state + di
            elif kind == "rwkv6":
                rc = self.rwkv or RWKVConfig()
                n += d * d * 5 + d * d                       # r,k,v,g,o + gate-ish
                n += 2 * (d * rc.decay_lora + rc.decay_lora * d)
            if self.is_moe_layer(i):
                assert self.moe is not None
                e = self.moe
                per = d * e.d_ff_expert * (3 if self.glu else 2)
                n += e.num_experts * per + d * e.num_experts  # experts + router
                if e.shared_d_ff:
                    n += d * e.shared_d_ff * (3 if self.glu else 2)
            else:
                n += d * f * (3 if self.glu else 2)
        return n

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        n = self.param_count()
        e = self.moe
        per = self.d_model * e.d_ff_expert * (3 if self.glu else 2)
        moe_layers = sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))
        n -= moe_layers * (e.num_experts - e.top_k) * per
        return n

    def layer_mixer(self, i: int) -> str:
        if self.mixer == "attention":
            return "attention"
        if self.mixer == "mamba":
            return "mamba"
        if self.mixer == "rwkv6":
            return "rwkv6"
        if self.mixer == "hybrid":
            return "attention" if i % self.attn_every == 0 else "mamba"
        raise ValueError(f"unknown mixer {self.mixer}")

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1)

    def supports_long_context(self) -> bool:
        """True when the long_500k decode cell is tractable: decode must be
        sub-quadratic in context length.  SSM/linear mixers carry O(1)
        state; hybrids qualify because only 1-in-attn_every layers keep a
        KV cache (linear-per-token decode, cache fits sharded)."""
        return self.mixer in ("mamba", "rwkv6", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells per arch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def tp(self) -> int:
        return self.shape[self.axes.index("model")] if "model" in self.axes else 1

    @property
    def dp(self) -> int:
        d = 1
        for ax in ("pod", "data"):
            if ax in self.axes:
                d *= self.shape[self.axes.index(ax)]
        return d


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
SMOKE_MESH = MeshConfig((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    # distributed-optimization tricks
    grad_compression: str = "none"     # none | int8  (cross-pod all-reduce)
    opt_dtype: str = "float32"         # adam m/v dtype (bfloat16 halves
    #   optimizer HBM; pairs with fp32 master params)
    accum_dtype: str = "float32"       # grad-accumulation buffer dtype
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
