"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

Backbone only: EnCodec tokenization + codebook interleaving are upstream;
the conditioning (text/melody) frontend is a STUB delivering 64
precomputed embeddings.  48L, d=1536, MHA (kv=24), GeLU MLP (no GLU),
vocab 2048 (EnCodec codebook).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    glu=False,
    frontend="audio",
    frontend_tokens=64,
    remat=True,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    glu=False,
    frontend="audio",
    frontend_tokens=8,
    remat=False,
)
