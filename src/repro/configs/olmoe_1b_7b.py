"""OLMoE-1B-7B — MoE, 64 experts top-8 on every layer [arXiv:2409.02060].

16L, d=2048, MHA (kv=16), per-expert SwiGLU hidden 1024, vocab 50304.
EP: 64 experts shard 4-per-device over the 16-way model axis.
"""
from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024, every=1),
    remat=True,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, every=1),
    remat=False,
)
