"""Qwen3-14B — dense, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B family].

40L, d=5120, 40 heads x head_dim 128 (heads pad 40->48 at tp=16;
DESIGN.md §5), SwiGLU 17408, 151936 vocab, theta 1e6, RMSNorm on q/k per
head (the qk_norm flag).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    remat=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    rope_theta=1e6,
    remat=False,
)
