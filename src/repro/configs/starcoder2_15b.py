"""StarCoder2-15B — dense, GQA kv=4, sliding-window 4096
[arXiv:2402.19173].

40L, d=6144, 48 heads x 128, plain GeLU MLP (no GLU) 24576, vocab 49152.
kv=4 pads to KVp=16 at tp=16 (4x kv-cache replication; DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    act="gelu",
    glu=False,
    sliding_window=4096,
    rope_theta=100000.0,
    remat=True,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    act="gelu",
    glu=False,
    sliding_window=16,
    remat=False,
)
