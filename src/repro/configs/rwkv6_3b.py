"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay
[arXiv:2404.05892].

32L, d=2560 (40 heads x 64; pad to 48 at tp=16), channel-mix hidden 8960,
vocab 65536.  O(1) decode state (wkv matrix per head) — long_500k runs.
"""
from repro.configs.base import ModelConfig, RWKVConfig

FULL = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    mixer="rwkv6",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
    remat=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=128,
    vocab_size=512,
    mixer="rwkv6",
    rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=8),
    remat=False,
)
