"""Llama-3.1 405B — dense, GQA kv=8, 128k vocab [arXiv:2407.21783].

126L, d=16384, 128 heads x 128, SwiGLU 53248, theta 500000.  The memory
stress case: FSDP parameter sharding over the data axis (2D
(data, model) sharding — ZeRO-3 analogue), full remat, and 4-way
microbatch gradient accumulation are required to fit
params+Adam+activations in 16 GB/chip at 512 chips (see EXPERIMENTS.md
§Dry-run memory analysis).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    remat=True,
    remat_group=9,        # sqrt remat over the 126-layer stack (14 x 9)
    fsdp=True,
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    rope_theta=500000.0,
    remat=False,
    fsdp=False,
    grad_accum=2,
)
