"""fleetlint core: finding model, pass registry, baseline, and driver.

The analyzer is pure ``ast`` — nothing is imported or compiled, so a
lint run cannot be perturbed by (or perturb) jax state, and it runs in
milliseconds in CI before any test or benchmark spins up.  Passes come
in two shapes:

* **file passes** get one parsed module at a time (clock purity, jit
  hygiene, allocator encapsulation, exception hygiene);
* **project passes** get the repo root and cross-reference several
  files (kernel contracts vs ``kernels/``, telemetry schema vs the
  golden test).

Findings are identified by a *stable key* ``path::CODE::symbol`` (the
enclosing function/class qualname, falling back to the line number), so
the checked-in baseline survives unrelated edits to the same file.
Every baseline entry must carry a non-empty ``reason`` — the allowlist
is documentation, not a mute button — and entries that no longer match
anything are reported as stale so the file can only shrink.
"""
from __future__ import annotations

import ast
import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

LINT_VERSION = 1

#: subtree of the repo the analyzer walks (repo-relative, posix)
SOURCE_ROOT = "src/repro"


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Finding:
    """One invariant violation at one site."""
    pass_id: str          # "clock" | "jit" | "alloc" | "kernel" | ...
    code: str             # e.g. "VCP001"
    path: str             # repo-relative posix path
    line: int
    message: str
    symbol: str = ""      # enclosing def/class qualname ("" = module level)

    @property
    def key(self) -> str:
        """Stable suppression key (symbol-scoped, line-independent)."""
        anchor = self.symbol if self.symbol else f"L{self.line}"
        return f"{self.path}::{self.code}::{anchor}"

    def to_dict(self) -> Dict:
        return {"pass": self.pass_id, "code": self.code, "path": self.path,
                "line": self.line, "symbol": self.symbol,
                "message": self.message, "key": self.key}


class FileContext:
    """One parsed source file handed to every file pass."""

    def __init__(self, root: str, rel: str, source: str,
                 tree: Optional[ast.AST] = None):
        self.root = root
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.tree = tree if tree is not None else ast.parse(source, rel)
        self._annotate_symbols()

    def _annotate_symbols(self) -> None:
        """Stamp every node with its enclosing def/class qualname."""
        def walk(node: ast.AST, stack: Tuple[str, ...]) -> None:
            for child in ast.iter_child_nodes(node):
                nstack = stack
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    nstack = stack + (child.name,)
                child._fl_qual = ".".join(stack)       # enclosing scope
                walk(child, nstack)
        self.tree._fl_qual = ""
        walk(self.tree, ())

    def symbol(self, node: ast.AST) -> str:
        return getattr(node, "_fl_qual", "")

    def finding(self, pass_id: str, code: str, node: ast.AST,
                message: str) -> Finding:
        return Finding(pass_id, code, self.rel,
                       getattr(node, "lineno", 0), message,
                       symbol=self.symbol(node))


# ---------------------------------------------------------------------------
# pass registry
# ---------------------------------------------------------------------------
FilePass = Callable[[FileContext], List[Finding]]
ProjectPass = Callable[[str], List[Finding]]

FILE_PASSES: Dict[str, FilePass] = {}
PROJECT_PASSES: Dict[str, ProjectPass] = {}


def file_pass(name: str):
    def deco(fn: FilePass) -> FilePass:
        FILE_PASSES[name] = fn
        return fn
    return deco


def project_pass(name: str):
    def deco(fn: ProjectPass) -> ProjectPass:
        PROJECT_PASSES[name] = fn
        return fn
    return deco


def _load_passes() -> None:
    """Import the pass modules (registration is a side effect)."""
    from repro.analysis.passes import (allocator, clock, hygiene,  # noqa: F401
                                       jitcheck, kernels, telemetry)


# ---------------------------------------------------------------------------
# baseline / suppressions
# ---------------------------------------------------------------------------
DEFAULT_BASELINE = os.path.join("src", "repro", "analysis", "baseline.json")


class BaselineError(ValueError):
    """Malformed suppression file — fail the run, never skip silently."""


def load_baseline(path: str) -> Dict[str, str]:
    """Load ``{key: reason}``; every entry must carry a reason."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "suppressions" not in data:
        raise BaselineError(f"{path}: expected {{'suppressions': [...]}}")
    out: Dict[str, str] = {}
    for i, entry in enumerate(data["suppressions"]):
        key = entry.get("key")
        reason = entry.get("reason", "")
        if not key or not isinstance(key, str):
            raise BaselineError(f"{path}: suppression #{i} has no key")
        if not reason or not str(reason).strip():
            raise BaselineError(
                f"{path}: suppression {key!r} has no reason — every "
                f"allowlist entry must justify itself")
        if key in out:
            raise BaselineError(f"{path}: duplicate suppression {key!r}")
        out[key] = str(reason)
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
@dataclass
class Report:
    root: str
    findings: List[Finding] = field(default_factory=list)     # unsuppressed
    suppressed: List[Tuple[Finding, str]] = field(default_factory=list)
    stale_suppressions: List[str] = field(default_factory=list)
    parse_errors: List[Tuple[str, str]] = field(default_factory=list)
    files_scanned: int = 0

    @property
    def clean(self) -> bool:
        return not (self.findings or self.stale_suppressions
                    or self.parse_errors)

    def to_dict(self) -> Dict:
        by_pass: Dict[str, int] = {}
        for f in self.findings:
            by_pass[f.pass_id] = by_pass.get(f.pass_id, 0) + 1
        return {
            "version": LINT_VERSION,
            "root": self.root,
            "files_scanned": self.files_scanned,
            "clean": self.clean,
            "counts": {"findings": len(self.findings),
                       "suppressed": len(self.suppressed),
                       "stale_suppressions": len(self.stale_suppressions),
                       "parse_errors": len(self.parse_errors),
                       "by_pass": dict(sorted(by_pass.items()))},
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [{**f.to_dict(), "reason": r}
                           for f, r in self.suppressed],
            "stale_suppressions": list(self.stale_suppressions),
            "parse_errors": [{"path": p, "error": e}
                             for p, e in self.parse_errors],
        }


def default_root() -> str:
    """Repo root = parent of the ``src`` directory this package lives in."""
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def iter_source_files(root: str) -> List[str]:
    """Repo-relative paths of every python file under ``src/repro``."""
    base = os.path.join(root, *SOURCE_ROOT.split("/"))
    out = []
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for name in sorted(filenames):
            if name.endswith(".py"):
                rel = os.path.relpath(os.path.join(dirpath, name), root)
                out.append(rel.replace(os.sep, "/"))
    return out


def lint_file(root: str, rel: str,
              passes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the file passes over one file (``rel`` repo-relative)."""
    _load_passes()
    with open(os.path.join(root, rel.replace("/", os.sep))) as f:
        source = f.read()
    ctx = FileContext(root, rel, source)
    findings: List[Finding] = []
    for name, fn in FILE_PASSES.items():
        if passes is None or name in passes:
            findings.extend(fn(ctx))
    return findings


def run_lint(root: Optional[str] = None,
             files: Optional[Sequence[str]] = None,
             baseline_path: Optional[str] = DEFAULT_BASELINE,
             passes: Optional[Sequence[str]] = None) -> Report:
    """Lint the tree (or just ``files``) and apply the baseline.

    ``files`` restricts the *file* passes (``--diff`` mode); project
    passes still run whenever their subject files are in scope — they
    are whole-repo invariants and cheap.  ``baseline_path`` is
    repo-relative (or absolute); ``None`` disables suppression.
    """
    _load_passes()
    root = root if root is not None else default_root()
    report = Report(root=root)

    rels = list(files) if files is not None else iter_source_files(root)
    raw: List[Finding] = []
    for rel in rels:
        rel = rel.replace(os.sep, "/")
        try:
            with open(os.path.join(root, rel.replace("/", os.sep))) as f:
                source = f.read()
            ctx = FileContext(root, rel, source)
        except (OSError, SyntaxError) as e:
            report.parse_errors.append((rel, str(e)))
            continue
        report.files_scanned += 1
        for name, fn in FILE_PASSES.items():
            if passes is None or name in passes:
                raw.extend(fn(ctx))

    for name, fn in PROJECT_PASSES.items():
        if passes is not None and name not in passes:
            continue
        if files is not None and not _project_pass_in_scope(name, rels):
            continue
        raw.extend(fn(root))

    raw.sort(key=lambda f: (f.path, f.line, f.code))

    suppressions: Dict[str, str] = {}
    if baseline_path is not None:
        bp = (baseline_path if os.path.isabs(baseline_path)
              else os.path.join(root, baseline_path))
        if os.path.exists(bp):
            suppressions = load_baseline(bp)

    used = set()
    for f in raw:
        reason = suppressions.get(f.key)
        if reason is not None:
            report.suppressed.append((f, reason))
            used.add(f.key)
        else:
            report.findings.append(f)
    # stale entries only assessable on a full run: a --diff slice that
    # skips a file must not report its suppressions as dead
    if files is None:
        report.stale_suppressions = sorted(set(suppressions) - used)
    return report


#: project passes only fire in --diff mode when a file they read changed
_PROJECT_SCOPE = {
    "kernel": ("src/repro/kernels/",
               "src/repro/analysis/passes/contracts.py"),
    "telemetry": ("src/repro/router/telemetry.py", "tests/test_obs.py"),
}


def _project_pass_in_scope(name: str, rels: Iterable[str]) -> bool:
    prefixes = _PROJECT_SCOPE.get(name)
    if prefixes is None:
        return True
    return any(r.startswith(p) for r in rels for p in prefixes)
