"""fleetlint CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings (or stale suppressions / parse
errors), 2 usage or baseline-format error.  ``--json`` emits the full
machine-readable report (the same payload CI uploads as
``LINT_report.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.core import (DEFAULT_BASELINE, FILE_PASSES,
                                 PROJECT_PASSES, BaselineError, Report,
                                 _load_passes, default_root, run_lint)


def _format_text(report: Report) -> str:
    lines: List[str] = []
    for f in report.findings:
        sym = f" [{f.symbol}]" if f.symbol else ""
        lines.append(f"{f.path}:{f.line}: {f.code}{sym} {f.message}")
    for key in report.stale_suppressions:
        lines.append(f"baseline: stale suppression {key!r} — remove it")
    for path, err in report.parse_errors:
        lines.append(f"{path}: parse error: {err}")
    n = len(report.findings)
    lines.append(
        f"fleetlint: {report.files_scanned} files, {n} finding"
        f"{'' if n == 1 else 's'}, {len(report.suppressed)} suppressed, "
        f"{len(report.stale_suppressions)} stale")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fleetlint — static invariant analyzer for src/repro")
    parser.add_argument("paths", nargs="*",
                        help="repo-relative files to lint (default: all of "
                             "src/repro)")
    parser.add_argument("--root", default=None,
                        help="repo root (default: autodetected)")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable report")
    parser.add_argument("--output", default=None, metavar="FILE",
                        help="also write the JSON report to FILE")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="suppression file (repo-relative)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline (show every finding)")
    parser.add_argument("--pass", dest="passes", action="append",
                        default=None, metavar="NAME",
                        help="run only this pass (repeatable)")
    parser.add_argument("--list-passes", action="store_true")
    args = parser.parse_args(argv)

    _load_passes()
    if args.list_passes:
        for name in sorted(FILE_PASSES):
            print(f"{name}\t(file pass)")
        for name in sorted(PROJECT_PASSES):
            print(f"{name}\t(project pass)")
        return 0

    known = set(FILE_PASSES) | set(PROJECT_PASSES)
    if args.passes and not set(args.passes) <= known:
        bad = sorted(set(args.passes) - known)
        print(f"fleetlint: unknown pass(es) {bad}; known: {sorted(known)}",
              file=sys.stderr)
        return 2

    try:
        report = run_lint(
            root=args.root if args.root is not None else default_root(),
            files=args.paths if args.paths else None,
            baseline_path=None if args.no_baseline else args.baseline,
            passes=args.passes)
    except BaselineError as e:
        print(f"fleetlint: {e}", file=sys.stderr)
        return 2

    payload = json.dumps(report.to_dict(), indent=2, sort_keys=True)
    if args.output:
        with open(args.output, "w") as f:
            f.write(payload + "\n")
    if args.json:
        print(payload)
    else:
        print(_format_text(report))
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
