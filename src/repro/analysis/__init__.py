"""fleetlint: static invariant analysis for the serving stack.

Pure-``ast`` passes over ``src/repro`` that keep the repo's tested
invariants from regressing silently: virtual-clock purity, jit-boundary
hygiene, allocator-accounting encapsulation, kernel contracts, and
exception/telemetry-schema hygiene.  See ``analysis/README.md``.
"""
from repro.analysis.core import (BaselineError, DEFAULT_BASELINE,  # noqa: F401
                                 FILE_PASSES, Finding, PROJECT_PASSES,
                                 Report, lint_file, load_baseline,
                                 run_lint)

__all__ = ["BaselineError", "DEFAULT_BASELINE", "FILE_PASSES", "Finding",
           "PROJECT_PASSES", "Report", "lint_file", "load_baseline",
           "run_lint"]
