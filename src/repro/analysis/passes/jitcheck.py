"""Pass 2 — jit-boundary hygiene (JIT).

The fused decode dispatch is fast *because* nothing inside it touches
the host: an accidental ``bool(traced)``, ``.item()``, or ``np.`` call
inside a jitted program forces a device sync per step (or a tracer
error at best), and a dict-valued static arg or a closure-captured
mutable recompiles the program on every call.  This pass finds jitted
contexts statically — ``@jax.jit`` / ``@functools.partial(jax.jit,
...)`` decorators, ``jax.jit(fn)`` / ``jax.jit(self._method)`` /
``jax.jit(lambda ...)`` call sites — and flags inside them:

* ``JIT001`` — host conversion of a traced value: ``bool()`` /
  ``int()`` / ``float()`` over an expression mentioning a traced
  parameter, or any ``.item()`` / ``.tolist()`` call.
* ``JIT002`` — host-library call: any use of the ``numpy`` module (the
  host ``np``, not ``jnp``) inside a jitted body.
* ``JIT003`` — Python control flow on a traced argument: ``if`` /
  ``while`` whose test mentions a traced parameter.  Parameters named
  in ``static_argnums`` / ``static_argnames`` and names derived from
  ``.shape`` / ``.ndim`` / ``.dtype`` are known static and exempt.
* ``JIT004`` — recompile hazard: a static arg whose default is a
  dict/list/set (unhashable — every call is a cache miss).
* ``JIT005`` — recompile hazard: a jitted closure capturing a mutable
  (list/dict/set) binding from its enclosing function scope.

``assert`` statements are exempt (shape checks on static values are
idiomatic), as are reads through ``self.`` (bound configuration).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import FileContext, Finding, file_pass

MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                    ast.DictComp, ast.SetComp)
MUTABLE_CTORS = frozenset({"list", "dict", "set", "defaultdict",
                           "OrderedDict", "deque"})
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "itemsize"})


class _Aliases:
    def __init__(self, tree: ast.AST):
        self.jax: Set[str] = set()
        self.jit: Set[str] = set()           # from jax import jit
        self.partial: Set[str] = set()       # partial / functools.partial
        self.functools: Set[str] = set()
        self.np: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    alias = a.asname or a.name
                    if a.name == "jax":
                        self.jax.add(alias)
                    elif a.name == "functools":
                        self.functools.add(alias)
                    elif a.name == "numpy":
                        self.np.add(alias)
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    alias = a.asname or a.name
                    if node.module == "jax" and a.name == "jit":
                        self.jit.add(alias)
                    elif (node.module == "functools"
                          and a.name == "partial"):
                        self.partial.add(alias)
                    elif node.module == "numpy" and a.name is not None:
                        pass                 # from numpy import X: ignore

    def is_jit(self, fn: ast.AST) -> bool:
        """Is ``fn`` an expression naming ``jax.jit``?"""
        if isinstance(fn, ast.Name):
            return fn.id in self.jit
        return (isinstance(fn, ast.Attribute) and fn.attr == "jit"
                and isinstance(fn.value, ast.Name)
                and fn.value.id in self.jax)

    def is_partial(self, fn: ast.AST) -> bool:
        if isinstance(fn, ast.Name):
            return fn.id in self.partial
        return (isinstance(fn, ast.Attribute) and fn.attr == "partial"
                and isinstance(fn.value, ast.Name)
                and fn.value.id in self.functools)


def _static_info(call: ast.Call) -> Tuple[List[int], List[str]]:
    """Extract static_argnums / static_argnames from a jit(...) call."""
    nums: List[int] = []
    names: List[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            nums.extend(_const_ints(kw.value))
        elif kw.arg == "static_argnames":
            names.extend(_const_strs(kw.value))
    return nums, names


def _const_ints(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            out.extend(_const_ints(el))
        return out
    return []


def _const_strs(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            out.extend(_const_strs(el))
        return out
    return []


def _param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
    names += [p.arg for p in a.kwonlyargs]
    return names


def _param_defaults(fn: ast.AST) -> Dict[str, ast.AST]:
    """Map param name -> default expression (positional + kwonly)."""
    a = fn.args
    pos = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
    out: Dict[str, ast.AST] = {}
    for name, default in zip(reversed(pos), reversed(a.defaults)):
        out[name] = default
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            out[p.arg] = d
    return out


class _Scopes(ast.NodeVisitor):
    """Local defs / simple assignments per function scope, class methods
    per class — the resolution tables for ``jax.jit(<name>)`` and
    ``jax.jit(self.<method>)`` call sites."""

    def __init__(self, tree: ast.AST):
        self.defs: Dict[int, Dict[str, ast.AST]] = {}       # scope -> defs
        self.assigns: Dict[int, Dict[str, ast.AST]] = {}    # scope -> exprs
        self.methods: Dict[int, Dict[str, ast.AST]] = {}    # class -> defs
        self.parent_scope: Dict[int, Optional[ast.AST]] = {}
        self.enclosing_class: Dict[int, Optional[ast.AST]] = {}
        self._stack: List[ast.AST] = [tree]
        self._class: List[Optional[ast.AST]] = [None]
        self.defs[id(tree)] = {}
        self.assigns[id(tree)] = {}
        self.generic_visit(tree)

    def _record(self, name: str, node: ast.AST) -> None:
        self.defs[id(self._stack[-1])][name] = node
        if self._class[-1] is not None and self._stack[-1] is self._class[-1]:
            self.methods.setdefault(id(self._class[-1]), {})[name] = node

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._record(node.name, node)
        self.methods.setdefault(id(node), {})
        self.parent_scope[id(node)] = self._stack[-1]
        self._stack.append(node)
        self._class.append(node)
        self.defs[id(node)] = {}
        self.assigns[id(node)] = {}
        self.generic_visit(node)
        self._stack.pop()
        self._class.pop()

    def _visit_fn(self, node) -> None:
        self._record(node.name, node)
        self.parent_scope[id(node)] = self._stack[-1]
        self.enclosing_class[id(node)] = self._class[-1]
        self._stack.append(node)
        self._class.append(None)       # methods of nested classes re-push
        self.defs[id(node)] = {}
        self.assigns[id(node)] = {}
        self.generic_visit(node)
        self._stack.pop()
        self._class.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # lambdas hold no assignments, but their enclosing scope matters
        # for closure-capture analysis (JIT005)
        self.parent_scope[id(node)] = self._stack[-1]
        self.enclosing_class[id(node)] = None
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                self.assigns[id(self._stack[-1])][tgt.id] = node.value
        self.generic_visit(node)

    def resolve(self, scope: ast.AST, name: str) -> Optional[ast.AST]:
        """Find a def named ``name`` walking scopes outward."""
        cur: Optional[ast.AST] = scope
        while cur is not None:
            d = self.defs.get(id(cur), {})
            if name in d:
                return d[name]
            cur = self.parent_scope.get(id(cur))
        return None


@file_pass("jit")
def jit_pass(ctx: FileContext) -> List[Finding]:
    aliases = _Aliases(ctx.tree)
    if not (aliases.jax or aliases.jit):
        return []
    scopes = _Scopes(ctx.tree)

    # ---- discover jit contexts --------------------------------------
    # context: (fn_node, traced_param_names, is_bound_method)
    contexts: Dict[int, Tuple[ast.AST, List[str]]] = {}
    findings: List[Finding] = []

    def add_context(fn: ast.AST, static_nums: Sequence[int],
                    static_names: Sequence[str], bound: bool) -> None:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            return
        params = _param_names(fn)
        if params and params[0] == "self" and (
                bound or scopes.enclosing_class.get(id(fn)) is not None):
            params = params[1:]
        static = {params[i] for i in static_nums if 0 <= i < len(params)}
        static.update(static_names)
        traced = [p for p in params if p not in static]
        contexts[id(fn)] = (fn, traced)
        # JIT004: unhashable static defaults
        defaults = _param_defaults(fn)
        for name in sorted(static):
            d = defaults.get(name)
            if d is not None and (isinstance(d, MUTABLE_LITERALS) or (
                    isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                    and d.func.id in MUTABLE_CTORS)):
                findings.append(ctx.finding(
                    "jit", "JIT004", fn,
                    f"static arg {name!r} defaults to an unhashable "
                    f"container — every jit call is a cache miss "
                    f"(recompile); use a hashable static or close over "
                    f"it"))

    def jit_decorator(dec: ast.AST):
        """Return (static_nums, static_names) if ``dec`` is jit-like."""
        if aliases.is_jit(dec):
            return [], []
        if isinstance(dec, ast.Call):
            if aliases.is_jit(dec.func):
                return _static_info(dec)
            if (aliases.is_partial(dec.func) and dec.args
                    and aliases.is_jit(dec.args[0])):
                return _static_info(dec)
        return None

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                info = jit_decorator(dec)
                if info is not None:
                    add_context(node, info[0], info[1], bound=False)
        elif isinstance(node, ast.Call) and aliases.is_jit(node.func):
            if not node.args:
                continue
            target = node.args[0]
            nums, names = _static_info(node)
            if isinstance(target, ast.Lambda):
                add_context(target, nums, names, bound=False)
            elif isinstance(target, ast.Name):
                scope = _scope_of(node, scopes, ctx)
                fn = scopes.resolve(scope, target.id) if scope else None
                if fn is not None:
                    add_context(fn, nums, names, bound=False)
            elif (isinstance(target, ast.Attribute)
                  and isinstance(target.value, ast.Name)
                  and target.value.id == "self"):
                cls = _enclosing_class_of(node, scopes, ctx)
                fn = (scopes.methods.get(id(cls), {}).get(target.attr)
                      if cls is not None else None)
                if fn is not None:
                    add_context(fn, nums, names, bound=True)

    # ---- analyze each context ---------------------------------------
    for fn, traced in contexts.values():
        findings.extend(_check_body(ctx, aliases, scopes, fn, traced))
    return findings


def _scope_of(node: ast.AST, scopes: _Scopes,
              ctx: FileContext) -> Optional[ast.AST]:
    """Innermost function/module scope a call site sits in, recovered
    from the qualname annotation (class scopes resolve to their
    parent)."""
    qual = ctx.symbol(node)
    cur: ast.AST = ctx.tree
    if qual:
        for part in qual.split("."):
            d = scopes.defs.get(id(cur), {})
            nxt = d.get(part)
            if nxt is None:
                break
            cur = nxt
    if isinstance(cur, ast.ClassDef):
        return scopes.parent_scope.get(id(cur), ctx.tree)
    return cur


def _enclosing_class_of(node: ast.AST, scopes: _Scopes,
                        ctx: FileContext) -> Optional[ast.AST]:
    qual = ctx.symbol(node)
    cur: ast.AST = ctx.tree
    cls: Optional[ast.AST] = None
    if qual:
        for part in qual.split("."):
            d = scopes.defs.get(id(cur), {})
            nxt = d.get(part)
            if nxt is None:
                break
            if isinstance(nxt, ast.ClassDef):
                cls = nxt
            cur = nxt
    return cls


def _body_nodes(fn: ast.AST):
    return fn.body if isinstance(fn.body, list) else [fn.body]


def _check_body(ctx: FileContext, aliases: _Aliases, scopes: _Scopes,
                fn: ast.AST, traced: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    static_names: Set[str] = set()
    traced_set = set(traced)

    # names derived from shapes/dtypes are static: iterate to fixpoint
    assigns = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
    for _ in range(3):
        grew = False
        for a in assigns:
            if _is_static_expr(a.value, static_names, traced_set):
                for tgt in a.targets:
                    for name in _target_names(tgt):
                        if name not in static_names:
                            static_names.add(name)
                            grew = True
        if not grew:
            break
    traced_set -= static_names

    def mentions_traced(expr: ast.AST) -> bool:
        return any(isinstance(n, ast.Name) and n.id in traced_set
                   for n in ast.walk(expr))

    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            continue
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
            if _shape_guarded(test) or not mentions_traced(test):
                continue
            kind = "while" if isinstance(node, ast.While) else "if"
            findings.append(ctx.finding(
                "jit", "JIT003", node,
                f"Python `{kind}` on a traced argument inside a jitted "
                f"function — forces a host sync (TracerBoolConversion); "
                f"use jnp.where / lax.cond, or declare the arg static"))
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Name) and f.id in ("bool", "int", "float")
                    and node.args and mentions_traced(node.args[0])):
                findings.append(ctx.finding(
                    "jit", "JIT001", node,
                    f"host conversion {f.id}() of a traced value inside "
                    f"a jitted function — implicit device sync"))
            elif (isinstance(f, ast.Attribute)
                  and f.attr in ("item", "tolist")):
                findings.append(ctx.finding(
                    "jit", "JIT001", node,
                    f".{f.attr}() inside a jitted function — implicit "
                    f"device sync on every call"))
        elif (isinstance(node, ast.Name) and node.id in aliases.np
              and isinstance(node.ctx, ast.Load)):
            findings.append(ctx.finding(
                "jit", "JIT002", node,
                "host numpy call inside a jitted function — runs at "
                "trace time or forces a sync; use jnp"))

    # JIT005: closure-captured mutables (nested contexts only)
    parent = scopes.parent_scope.get(id(fn))
    if parent is not None and not isinstance(parent, ast.Module):
        local = set(traced) | static_names | {"self"}
        for a in assigns:
            for tgt in a.targets:
                local.update(_target_names(tgt))
        for n in ast.walk(fn):
            if isinstance(n, (ast.FunctionDef, ast.Lambda)):
                local.update(_param_names(n))
            if isinstance(n, ast.FunctionDef):
                local.add(n.name)
        seen: Set[str] = set()
        for n in ast.walk(fn):
            if (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                    and n.id not in local and n.id not in seen):
                seen.add(n.id)
                enc = scopes.assigns.get(id(parent), {}).get(n.id)
                if enc is not None and (
                        isinstance(enc, MUTABLE_LITERALS)
                        or (isinstance(enc, ast.Call)
                            and isinstance(enc.func, ast.Name)
                            and enc.func.id in MUTABLE_CTORS)):
                    findings.append(ctx.finding(
                        "jit", "JIT005", n,
                        f"jitted closure captures mutable {n.id!r} from "
                        f"the enclosing scope — mutation after trace is "
                        f"silently ignored and identity changes "
                        f"recompile; pass it as an argument"))
    return findings


def _target_names(tgt: ast.AST) -> List[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for el in tgt.elts:
            out.extend(_target_names(el))
        return out
    return []


def _shape_guarded(test: ast.AST) -> bool:
    """Tests that only touch `.shape`-ish metadata are trace-static."""
    names = [n for n in ast.walk(test) if isinstance(n, ast.Name)]
    attrs = [n for n in ast.walk(test) if isinstance(n, ast.Attribute)]
    return bool(attrs) and all(a.attr in STATIC_ATTRS for a in attrs) \
        and all(any(isinstance(p, ast.Attribute) for p in ast.walk(test))
                for _ in names)


def _is_static_expr(expr: ast.AST, static: Set[str],
                    traced: Set[str]) -> bool:
    """Conservatively true when ``expr`` is shape/constant-derived."""
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Name):
        return expr.id in static
    if isinstance(expr, ast.Attribute):
        if expr.attr in STATIC_ATTRS:
            return True
        return False
    if isinstance(expr, ast.Subscript):
        return _is_static_expr(expr.value, static, traced)
    if isinstance(expr, ast.BinOp):
        return (_is_static_expr(expr.left, static, traced)
                and _is_static_expr(expr.right, static, traced))
    if isinstance(expr, ast.UnaryOp):
        return _is_static_expr(expr.operand, static, traced)
    if isinstance(expr, ast.Tuple):
        return all(_is_static_expr(e, static, traced) for e in expr.elts)
    if isinstance(expr, ast.Call):
        fname = None
        if isinstance(expr.func, ast.Name):
            fname = expr.func.id
        elif isinstance(expr.func, ast.Attribute):
            fname = expr.func.attr
        if fname in ("len", "min", "max", "sqrt", "ceil", "floor", "abs",
                     "round", "int", "float"):
            return all(_is_static_expr(a, static, traced)
                       for a in expr.args)
    return False
