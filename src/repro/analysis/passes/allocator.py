"""Pass 3 — allocator-accounting encapsulation (ALC).

``tests/test_paging.py`` property-tests the books:
``available + live + quarantined == num_blocks`` for
:class:`BlockAllocator`, refcount/digest agreement for
:class:`SharedBlockIndex`, digest-window bounds for
:class:`BlockDigestStore`.  Those proofs only hold if *every* mutation
goes through the sanctioned methods (``alloc`` / ``release`` /
``quarantine`` / ``acquire`` / ``register`` / ``seal`` / ``forget`` /
``purge`` ...).  This pass flags direct writes to the accounting state
from outside ``runtime/paging.py``:

* ``ALC001`` — mutation of a protected attribute (assignment,
  aug-assign, ``del``, or a mutating method call such as ``.append`` /
  ``.pop`` / ``.add`` / ``.clear``) on ``.free`` / ``.quarantined``
  (when the owner looks like an allocator) or on the always-private
  ``._sums`` / ``._cursor`` / ``._refs`` / ``._by_digest`` /
  ``._digest_of`` anywhere.

Reads are fine — ``len(self.alloc.quarantined)`` is how the scrubber
sizes its work queue.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import FileContext, Finding, file_pass

#: attributes private to paging.py no matter what object holds them
ALWAYS_PROTECTED = frozenset({"_sums", "_cursor", "_refs", "_by_digest",
                              "_digest_of"})
#: generic names — protected only when the owner expression smells like
#: an allocator (``alloc`` somewhere in its dotted path), to avoid
#: flagging unrelated ``.free`` attributes
ALLOCATOR_ATTRS = frozenset({"free", "quarantined"})

MUTATING_METHODS = frozenset({
    "append", "appendleft", "pop", "popleft", "remove", "add", "discard",
    "clear", "update", "insert", "extend", "setdefault", "sort",
    "reverse",
})

#: the one module allowed to touch the books directly
HOME = "src/repro/runtime/paging.py"


def _owner_mentions_alloc(node: ast.AST) -> bool:
    """True if the dotted owner path contains an allocator-ish name."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
    return any("alloc" in p.lower() for p in parts)


def _is_protected(attr: ast.Attribute) -> bool:
    if attr.attr in ALWAYS_PROTECTED:
        return True
    if attr.attr in ALLOCATOR_ATTRS and _owner_mentions_alloc(attr.value):
        return True
    return False


@file_pass("alloc")
def alloc_pass(ctx: FileContext) -> List[Finding]:
    if ctx.rel == HOME:
        return []
    findings: List[Finding] = []

    def flag(node: ast.AST, attr: str, how: str) -> None:
        findings.append(ctx.finding(
            "alloc", "ALC001", node,
            f"direct {how} of allocator accounting state .{attr} outside "
            f"runtime/paging.py — bypasses the property-tested books "
            f"(available + live + quarantined == num_blocks); use the "
            f"sanctioned allocator/index methods"))

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                # x.free = [...]  /  x._refs[k] += 1
                base = tgt
                if isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) and _is_protected(base):
                    flag(node, base.attr, "write")
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                base = tgt
                if isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) and _is_protected(base):
                    flag(node, base.attr, "delete")
        elif isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in MUTATING_METHODS
                    and isinstance(fn.value, ast.Attribute)
                    and _is_protected(fn.value)):
                flag(node, fn.value.attr, f"mutation (.{fn.attr}())")
    return findings
