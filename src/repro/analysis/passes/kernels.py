"""Pass 4 — kernel contracts (KRN), a project pass over ``kernels/``.

Checks every ``pl.pallas_call`` site in ``src/repro/kernels`` against
the declarative table in :mod:`repro.analysis.passes.contracts` without
compiling anything.  The checks mirror the ways a Pallas kernel breaks
silently (garbage DMA) or loudly at trace time:

* ``KRN001`` — pallas_call in a wrapper with no contract entry;
  ``KRN002`` — contract entry whose wrapper no longer exists (stale).
* ``KRN003`` — grid rank differs from the contract;
  ``KRN004`` — ``num_scalar_prefetch`` differs.
* ``KRN005`` — index-map arity != grid_rank + num_scalar_prefetch (the
  map would be called with the wrong number of program ids);
  ``KRN006`` — index-map return rank != BlockSpec block-shape rank.
* ``KRN007`` — index-map component reads a prefetched table by
  subscript without clamping (``jnp.maximum``/``minimum``/``clip``):
  a ``-1`` dead-entry sentinel would DMA out of bounds.
* ``KRN008`` — kernel body missing the contracted ``pl.when`` tail
  guard; uninitialized accumulators / dead-block MXU work.
* ``KRN009`` — ``dimension_semantics`` length or content differs from
  the contract.
* ``KRN010`` — wrapper missing the contracted divisibility ``assert``
  (`% block == 0`) ahead of the call.
* ``KRN011`` — ``out_shape`` dtype source differs from the contract
  (e.g. an int8 matmul silently widened to f32 accumulation output).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import FileContext, Finding, project_pass
from repro.analysis.passes.contracts import KERNEL_CONTRACTS, KernelContract

KERNELS_DIR = "src/repro/kernels"

CLAMP_FNS = frozenset({"maximum", "minimum", "clip", "clamp"})


def _mk(ctx: FileContext, code: str, node: ast.AST, msg: str,
        symbol: str = "") -> Finding:
    f = ctx.finding("kernel", code, node, msg)
    if symbol:
        f = Finding(f.pass_id, f.code, f.path, f.line, f.message,
                    symbol=symbol)
    return f


class _Wrapper:
    """One enclosing function that issues a pl.pallas_call."""

    def __init__(self, fn: ast.FunctionDef, call: ast.Call):
        self.fn = fn
        self.call = call
        self.assigns: Dict[str, ast.AST] = {}
        self.defs: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.assigns[tgt.id] = node.value
            elif isinstance(node, ast.FunctionDef) and node is not fn:
                self.defs[node.name] = node

    def resolve(self, node: ast.AST) -> ast.AST:
        """Follow one level of local Name indirection."""
        if isinstance(node, ast.Name) and node.id in self.assigns:
            return self.assigns[node.id]
        return node

    def kw(self, call: ast.Call, name: str) -> Optional[ast.AST]:
        for k in call.keywords:
            if k.arg == name:
                return self.resolve(k.value)
        return None


def _is_pallas_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == "pallas_call"
            and isinstance(f.value, ast.Name) and f.value.id == "pl")


def _index_maps(spec_call: ast.AST, w: _Wrapper):
    """Yield (block_shape_node, index_map_node) from a BlockSpec call."""
    spec_call = w.resolve(spec_call)
    if not isinstance(spec_call, ast.Call):
        return None
    args = list(spec_call.args)
    shape = args[0] if args else None
    imap = args[1] if len(args) > 1 else None
    for k in spec_call.keywords:
        if k.arg in ("block_shape",):
            shape = k.value
        elif k.arg in ("index_map",):
            imap = k.value
    return shape, imap


def _tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _map_fn(node: ast.AST, w: _Wrapper):
    """Resolve an index-map expression to (params, return_expr)."""
    node = w.resolve(node)
    if isinstance(node, ast.Lambda):
        return [a.arg for a in node.args.args], node.body
    if isinstance(node, ast.Name) and node.id in w.defs:
        fn = w.defs[node.id]
        rets = [n for n in ast.walk(fn) if isinstance(n, ast.Return)]
        ret = rets[0].value if rets else None
        return [a.arg for a in fn.args.args], ret
    return None, None


def _uses_pl_when(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "when"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "pl"):
            return True
    return False


def _has_mod_assert(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    return True
    return False


def _semantics_tuple(call: ast.Call, w: _Wrapper):
    """Find dimension_semantics=(...) anywhere in the call's keywords."""
    for kw in call.keywords:
        for node in ast.walk(kw.value):
            if (isinstance(node, ast.keyword)
                    and node.arg == "dimension_semantics"):
                v = w.resolve(node.value)
                if isinstance(v, (ast.Tuple, ast.List)):
                    return tuple(e.value for e in v.elts
                                 if isinstance(e, ast.Constant))
    return None


def _out_dtype_texts(call: ast.Call, w: _Wrapper) -> List[str]:
    out_shape = w.kw(call, "out_shape")
    if out_shape is None:
        return []
    structs = (out_shape.elts
               if isinstance(out_shape, (ast.Tuple, ast.List))
               else [out_shape])
    texts = []
    for s in structs:
        s = w.resolve(s)
        if isinstance(s, ast.Call) and len(s.args) >= 2:
            texts.append(ast.unparse(s.args[1]))
    return texts


def _check_site(ctx: FileContext, w: _Wrapper, name: str,
                c: KernelContract) -> List[Finding]:
    out: List[Finding] = []
    call = w.call

    def flag(code: str, node: ast.AST, msg: str) -> None:
        out.append(_mk(ctx, code, node, msg, symbol=name))

    # ---- grid / scalar prefetch -------------------------------------
    grid = w.kw(call, "grid")
    nsp = 0
    grid_spec = w.kw(call, "grid_spec")
    if grid_spec is not None and isinstance(grid_spec, ast.Call):
        g = None
        for k in grid_spec.keywords:
            if k.arg == "grid":
                g = w.resolve(k.value)
            elif k.arg == "num_scalar_prefetch":
                if isinstance(k.value, ast.Constant):
                    nsp = int(k.value.value)
        grid = g if g is not None else grid
    rank = _tuple_len(grid) if grid is not None else None
    if rank is not None and rank != c.grid_rank:
        flag("KRN003", call,
             f"grid rank {rank} != contracted {c.grid_rank} for {name}")
        rank = c.grid_rank          # keep arity checks anchored to contract
    if nsp != c.num_scalar_prefetch:
        flag("KRN004", call,
             f"num_scalar_prefetch {nsp} != contracted "
             f"{c.num_scalar_prefetch} for {name}")
    want_arity = c.grid_rank + c.num_scalar_prefetch

    # ---- index maps --------------------------------------------------
    specs: List[ast.AST] = []
    for src in ("in_specs", "out_specs"):
        v = w.kw(call, src)
        if v is None and grid_spec is not None and isinstance(grid_spec,
                                                             ast.Call):
            for k in grid_spec.keywords:
                if k.arg == src:
                    v = w.resolve(k.value)
        if v is None:
            continue
        if isinstance(v, (ast.Tuple, ast.List)):
            specs.extend(v.elts)
        else:
            specs.append(v)
    for spec in specs:
        pair = _index_maps(spec, w)
        if pair is None:
            continue
        shape, imap = pair
        if imap is None:
            continue
        params, ret = _map_fn(imap, w)
        if params is not None and len(params) != want_arity:
            flag("KRN005", spec,
                 f"index map takes {len(params)} args; grid supplies "
                 f"{want_arity} (grid_rank {c.grid_rank} + "
                 f"{c.num_scalar_prefetch} scalar-prefetch refs)")
        if ret is not None and shape is not None:
            rrank = _tuple_len(ret)
            srank = _tuple_len(w.resolve(shape))
            if rrank is not None and srank is not None and rrank != srank:
                flag("KRN006", spec,
                     f"index map returns {rrank} coords for a rank-"
                     f"{srank} block shape")
            if isinstance(ret, (ast.Tuple, ast.List)):
                for comp in ret.elts:
                    if _unclamped_subscript(comp):
                        flag("KRN007", spec,
                             "index-map component subscripts a prefetched "
                             "table without clamping — a -1 dead-entry "
                             "sentinel DMAs out of bounds; wrap in "
                             "jnp.maximum(..., 0)")

    # ---- kernel body guard ------------------------------------------
    body = _resolve_kernel_body(call, w)
    if body is not None:
        has_when = _uses_pl_when(body)
        if c.tail_guard and not has_when:
            flag("KRN008", call,
                 f"kernel body {body.name} has no pl.when guard but the "
                 f"contract requires tail/init predication")

    # ---- semantics / divisibility / dtype ---------------------------
    sem = _semantics_tuple(call, w)
    if c.dimension_semantics and sem is not None and \
            tuple(sem) != tuple(c.dimension_semantics):
        flag("KRN009", call,
             f"dimension_semantics {sem} != contracted "
             f"{c.dimension_semantics}")
    if c.divisibility_assert and not _has_mod_assert(w.fn):
        flag("KRN010", call,
             f"wrapper {name} missing the contracted divisibility "
             f"assert (% block == 0) ahead of pallas_call")
    texts = _out_dtype_texts(call, w)
    if c.out_dtypes and texts and tuple(texts) != tuple(c.out_dtypes):
        flag("KRN011", call,
             f"out_shape dtypes {texts} != contracted "
             f"{list(c.out_dtypes)}")
    return out


def _unclamped_subscript(comp: ast.AST) -> bool:
    """A bare table subscript (not wrapped in a clamp call)."""
    if isinstance(comp, ast.Subscript):
        return True
    if isinstance(comp, ast.Call):
        f = comp.func
        fname = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None)
        if fname in CLAMP_FNS:
            return False
        return any(isinstance(n, ast.Subscript) for a in comp.args
                   for n in ast.walk(a))
    if isinstance(comp, (ast.BinOp, ast.UnaryOp)):
        return any(_unclamped_subscript(n) for n in ast.iter_child_nodes(comp)
                   if not isinstance(n, ast.operator))
    return False


def _resolve_kernel_body(call: ast.Call, w: _Wrapper):
    if not call.args:
        return None
    target = w.resolve(call.args[0])
    # functools.partial(_kernel, ...) -> _kernel
    if isinstance(target, ast.Call) and target.args:
        inner = target.args[0]
        if isinstance(inner, ast.Name):
            target = inner
    if isinstance(target, ast.Name):
        return _MODULE_DEFS.get(target.id)
    return None


_MODULE_DEFS: Dict[str, ast.FunctionDef] = {}


@project_pass("kernel")
def kernel_pass(root: str) -> List[Finding]:
    findings: List[Finding] = []
    seen_wrappers: Dict[str, str] = {}      # wrapper name -> rel path

    base = os.path.join(root, *KERNELS_DIR.split("/"))
    if not os.path.isdir(base):
        return findings
    for fname in sorted(os.listdir(base)):
        if not fname.endswith(".py"):
            continue
        rel = f"{KERNELS_DIR}/{fname}"
        with open(os.path.join(base, fname)) as f:
            source = f.read()
        try:
            ctx = FileContext(root, rel, source)
        except SyntaxError:
            continue        # surfaced by the file-pass driver as a parse error

        _MODULE_DEFS.clear()
        for node in ast.iter_child_nodes(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                _MODULE_DEFS[node.name] = node

        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.FunctionDef)):
                continue
            calls = [n for n in ast.walk(node)
                     if isinstance(n, ast.Call) and _is_pallas_call(n)]
            if not calls:
                continue
            seen_wrappers[node.name] = rel
            contract = KERNEL_CONTRACTS.get(node.name)
            for call in calls:
                w = _Wrapper(node, call)
                if contract is None:
                    findings.append(_mk(
                        ctx, "KRN001", call,
                        f"pallas_call in {node.name} has no entry in "
                        f"analysis/passes/contracts.py — every kernel "
                        f"needs a declared contract", symbol=node.name))
                else:
                    findings.extend(_check_site(ctx, w, node.name,
                                                contract))

    for name, c in sorted(KERNEL_CONTRACTS.items()):
        if name not in seen_wrappers:
            findings.append(Finding(
                "kernel", "KRN002",
                "src/repro/analysis/passes/contracts.py", 0,
                f"stale kernel contract {name!r}: no pallas_call wrapper "
                f"of that name exists under {KERNELS_DIR}", symbol=name))
    return findings
