"""fleetlint passes — importing a module registers its pass."""
