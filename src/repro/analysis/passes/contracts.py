"""Declarative contracts for every Pallas kernel in ``src/repro/kernels``.

One entry per ``pl.pallas_call`` wrapper.  The kernel pass
(``passes/kernels.py``) checks each call site against its contract
*without compiling anything* — grid rank, scalar-prefetch count,
index-map arity and return rank, in-bounds discipline on table lookups,
``pl.when`` tail guards, dimension semantics, divisibility asserts, and
output dtype provenance.  Adding a kernel without a contract (or
leaving a stale contract behind) is itself a finding, so this table
stays the single authoritative inventory of device code.

``tail_guard`` is True when the kernel body must predicate work with
``pl.when`` (online-softmax init/finalize, accumulator init, length
masking).  ``rowwise_quant_pallas`` is the one full-tile kernel — every
grid step owns a complete row block (divisibility asserted in the
wrapper), so it has no tail to guard.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class KernelContract:
    module: str                    # repo-relative wrapper module
    kernel_fn: str                 # kernel body def the wrapper invokes
    grid_rank: int
    num_scalar_prefetch: int = 0
    tail_guard: bool = True        # body must use pl.when
    dimension_semantics: Tuple[str, ...] = ()
    divisibility_assert: bool = True   # wrapper asserts % block == 0
    out_dtypes: Tuple[str, ...] = ()   # source text of out_shape dtype(s)


KERNEL_CONTRACTS: Dict[str, KernelContract] = {
    "paged_attention_pallas": KernelContract(
        module="src/repro/kernels/paged_attention.py",
        kernel_fn="_paged_kernel",
        grid_rank=3,
        num_scalar_prefetch=2,     # block_table + lengths ride ahead
        tail_guard=True,           # dead-block predication + init/finalize
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        divisibility_assert=False,  # pool rows are whole pages by layout
        out_dtypes=("q.dtype",),
    ),
    "flash_attention_pallas": KernelContract(
        module="src/repro/kernels/flash_attention.py",
        kernel_fn="_flash_kernel",
        grid_rank=3,
        tail_guard=True,           # causal skip + online-softmax guards
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        divisibility_assert=True,
        out_dtypes=("q.dtype",),
    ),
    "int8_matmul_pallas": KernelContract(
        module="src/repro/kernels/int8_matmul.py",
        kernel_fn="_int8_matmul_kernel",
        grid_rank=3,
        tail_guard=True,           # k==0 accumulator init
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        divisibility_assert=True,
        out_dtypes=("jnp.int32",),
    ),
    "rowwise_quant_pallas": KernelContract(
        module="src/repro/kernels/quant.py",
        kernel_fn="_quant_kernel",
        grid_rank=1,
        tail_guard=False,          # full row blocks — no tail exists
        dimension_semantics=("parallel",),
        divisibility_assert=True,
        out_dtypes=("jnp.int8", "jnp.float32"),
    ),
    "selective_scan_pallas": KernelContract(
        module="src/repro/kernels/selective_scan.py",
        kernel_fn="_selective_scan_kernel",
        grid_rank=3,
        tail_guard=True,           # chunk-0 state init
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        divisibility_assert=True,
        out_dtypes=("x.dtype",),
    ),
    "wkv_pallas": KernelContract(
        module="src/repro/kernels/wkv.py",
        kernel_fn="_wkv_kernel",
        grid_rank=3,
        tail_guard=True,           # chunk-0 state init
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        divisibility_assert=True,
        out_dtypes=("r.dtype",),
    ),
}
