"""Pass 5b — telemetry-schema drift (TEL), a project pass.

``tests/test_obs.py`` pins the snapshot schema as golden set literals
(``FLEET_KEYS`` / ``POOL_KEYS`` / ``HIST_KEYS`` / ``DROP_REASONS`` /
``SLI_KEYS`` / ``SLI_SCOPES`` / ``ALERT_KEYS``) — the contract the
orbit controller, benches, and CI gates read.  But the golden test only
fails at *test time* on a traffic shape that exercises the key; this
pass closes the gap statically by diffing the dict literals in
``router/telemetry.py`` and ``obs/slo.py`` against the golden sets:

* ``TEL001`` — key written by ``Telemetry.snapshot()`` /
  ``PoolCounters.summary()`` / ``Histogram.summary()`` /
  ``SLIScope.summary()`` / ``SLIRegistry.summary()`` /
  ``AlertBus.snapshot()`` / the ``drops_by_reason`` zero-init that is
  **absent** from the golden set (schema grew without updating the
  contract).
* ``TEL002`` — golden key no monitored writer produces (schema
  shrank / key renamed — every dashboard reading it now KeyErrors).
* ``TEL003`` — a monitored writer or golden set could not be located
  at all (the pass itself went stale; fix the extraction anchors).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from repro.analysis.core import FileContext, Finding, project_pass

TELEMETRY_FILE = "src/repro/router/telemetry.py"
SLO_FILE = "src/repro/obs/slo.py"
GOLDEN_FILE = "tests/test_obs.py"

#: (file, class, method) -> golden set-literal name in the test file
WRITERS = {
    (TELEMETRY_FILE, "Telemetry", "snapshot"): "FLEET_KEYS",
    (TELEMETRY_FILE, "PoolCounters", "summary"): "POOL_KEYS",
    (TELEMETRY_FILE, "Histogram", "summary"): "HIST_KEYS",
    (SLO_FILE, "SLIScope", "summary"): "SLI_KEYS",
    (SLO_FILE, "SLIRegistry", "summary"): "SLI_SCOPES",
    (SLO_FILE, "AlertBus", "snapshot"): "ALERT_KEYS",
}
#: the zero-init reason dict must cover at least the golden reasons
DROPS_ATTR = "drops_by_reason"
DROPS_GOLDEN = "DROP_REASONS"


def _dict_keys(node: ast.AST) -> Optional[Set[str]]:
    if isinstance(node, ast.Dict):
        keys = set()
        for k in node.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
            else:
                return None          # computed key — can't check statically
        return keys
    return None


def _return_dict_keys(fn: ast.FunctionDef) -> Optional[Set[str]]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            keys = _dict_keys(node.value)
            if keys is not None:
                return keys
    return None


def _golden_sets(tree: ast.AST) -> Dict[str, Set[str]]:
    out: Dict[str, Set[str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if not isinstance(tgt, ast.Name):
                continue
            if isinstance(node.value, ast.Set):
                vals = {e.value for e in node.value.elts
                        if isinstance(e, ast.Constant)}
                out[tgt.id] = vals
    return out


@project_pass("telemetry")
def telemetry_pass(root: str) -> List[Finding]:
    findings: List[Finding] = []

    def read(rel: str) -> Optional[FileContext]:
        p = os.path.join(root, rel.replace("/", os.sep))
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return FileContext(root, rel, f.read())

    tctx = read(TELEMETRY_FILE)
    gctx = read(GOLDEN_FILE)
    if tctx is None or gctx is None:
        missing = TELEMETRY_FILE if tctx is None else GOLDEN_FILE
        return [Finding("telemetry", "TEL003", missing, 0,
                        f"telemetry pass anchor {missing} not found")]
    golden = _golden_sets(gctx.tree)

    # file -> class -> method defs, for every monitored writer file
    writer_files = sorted({f for f, _, _ in WRITERS})
    methods: Dict[str, Dict[str, Dict[str, ast.FunctionDef]]] = {}
    for rel in writer_files:
        ctx = tctx if rel == TELEMETRY_FILE else read(rel)
        if ctx is None:
            findings.append(Finding(
                "telemetry", "TEL003", rel, 0,
                f"telemetry pass anchor {rel} not found — re-anchor the "
                f"WRITERS table in passes/telemetry.py"))
            continue
        methods[rel] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                methods[rel][node.name] = {
                    n.name: n for n in node.body
                    if isinstance(n, ast.FunctionDef)}

    for (rel, cls, meth), golden_name in WRITERS.items():
        if rel not in methods:
            continue                  # missing file already reported
        fn = methods[rel].get(cls, {}).get(meth)
        want = golden.get(golden_name)
        if fn is None or want is None:
            where = (f"{cls}.{meth}" if fn is None
                     else f"{GOLDEN_FILE}:{golden_name}")
            findings.append(Finding(
                "telemetry", "TEL003", rel, 0,
                f"telemetry pass anchor {where} not found — re-anchor "
                f"the WRITERS table in passes/telemetry.py",
                symbol=f"{cls}.{meth}"))
            continue
        got = _return_dict_keys(fn)
        if got is None:
            continue                  # non-literal return: golden test covers it
        for key in sorted(got - want):
            findings.append(Finding(
                "telemetry", "TEL001", rel, fn.lineno,
                f"{cls}.{meth}() writes key {key!r} that is missing from "
                f"{golden_name} in {GOLDEN_FILE} — add it to the golden "
                f"schema in the same change", symbol=f"{cls}.{meth}"))
        for key in sorted(want - got):
            findings.append(Finding(
                "telemetry", "TEL002", rel, fn.lineno,
                f"golden key {key!r} in {golden_name} has no writer in "
                f"{cls}.{meth}() — consumers reading it will KeyError",
                symbol=f"{cls}.{meth}"))

    # drops_by_reason zero-init must cover the golden reason codes
    want = golden.get(DROPS_GOLDEN)
    init_keys: Optional[Set[str]] = None
    init_line = 0
    for node in ast.walk(tctx.tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Attribute)
                        and tgt.attr == DROPS_ATTR):
                    init_keys = _dict_keys(node.value)
                    init_line = node.lineno
        elif isinstance(node, ast.AnnAssign):
            tgt = node.target
            if (isinstance(tgt, ast.Attribute) and tgt.attr == DROPS_ATTR
                    and node.value is not None):
                init_keys = _dict_keys(node.value)
                init_line = node.lineno
    if want is None or init_keys is None:
        findings.append(Finding(
            "telemetry", "TEL003", TELEMETRY_FILE, 0,
            f"telemetry pass anchor {DROPS_ATTR} zero-init or "
            f"{DROPS_GOLDEN} golden set not found"))
    else:
        for key in sorted(init_keys - want):
            findings.append(Finding(
                "telemetry", "TEL001", TELEMETRY_FILE, init_line,
                f"drop reason {key!r} zero-initialized but missing from "
                f"{DROPS_GOLDEN} in {GOLDEN_FILE}", symbol="Telemetry"))
        for key in sorted(want - init_keys):
            findings.append(Finding(
                "telemetry", "TEL002", TELEMETRY_FILE, init_line,
                f"golden drop reason {key!r} is not zero-initialized in "
                f"{DROPS_ATTR} — the snapshot schema is traffic-dependent "
                f"for it", symbol="Telemetry"))
    return findings
