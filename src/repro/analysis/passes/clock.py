"""Pass 1 — virtual-clock purity (VCP).

The whole serving stack (router dispatch, orbit controller, obs spans,
traffic generation) runs on a *virtual* clock so seeded runs replay
bit-identically; the only sanctioned wall-clock reads are the
engine-stage timers in ``runtime/serve.py`` / ``serving/executor.py``,
which measure real device work and are re-anchored onto the virtual
timeline by the flight recorder.  This pass flags:

* ``VCP001`` — wall-clock reads: ``time.time`` / ``perf_counter`` /
  ``monotonic`` / ``process_time`` (+ ``_ns`` variants), ``time.sleep``,
  ``datetime.now`` / ``utcnow`` / ``today``.
* ``VCP002`` — nondeterministic RNG: module-global ``random.*`` calls,
  unseeded ``random.Random()`` / ``np.random.default_rng()``, legacy
  global ``np.random.<dist>`` draws, and ``np.random.seed`` (global
  state).  Seeded constructors (``random.Random(seed)``,
  ``default_rng(seed)``) and explicit-key ``jax.random`` are fine.

Sanctioned sites live in ``analysis/baseline.json``, each with a reason
— so reintroducing a wall-clock read anywhere else (say,
``router/dispatch.py``) fails CI before any chaos/obs gate has the
chance to flake on it.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import FileContext, Finding, file_pass

WALL_TIME_FNS = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns", "sleep",
})
DATETIME_NOW_FNS = frozenset({"now", "utcnow", "today"})
#: random-module attributes that are *not* global-RNG draws
RANDOM_NON_GLOBAL = frozenset({"Random", "SystemRandom", "getstate",
                               "setstate"})


def _is_name(node: ast.AST, name: str) -> bool:
    return isinstance(node, ast.Name) and node.id == name


@file_pass("clock")
def clock_pass(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    # track what this module calls `time` / `random` / `np` / `datetime`
    time_aliases, random_aliases, np_aliases, dt_aliases = (set(), set(),
                                                            set(), set())
    from_time: set = set()             # `from time import perf_counter`
    from_dt: set = set()               # `from datetime import datetime`

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                alias = a.asname or a.name
                if a.name == "time":
                    time_aliases.add(alias)
                elif a.name == "random":
                    random_aliases.add(alias)
                elif a.name == "numpy":
                    np_aliases.add(alias)
                elif a.name == "datetime":
                    dt_aliases.add(alias)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time":
                from_time.update(a.asname or a.name for a in node.names
                                 if a.name in WALL_TIME_FNS)
            elif node.module == "datetime":
                from_dt.update(a.asname or a.name for a in node.names
                               if a.name in ("datetime", "date"))
            elif node.module == "numpy":
                np_aliases.update(a.asname or a.name for a in node.names
                                  if a.name == "random")

    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        # time.<wall fn>()  /  bare perf_counter() from `from time import`
        if (isinstance(fn, ast.Attribute) and fn.attr in WALL_TIME_FNS
                and isinstance(fn.value, ast.Name)
                and fn.value.id in time_aliases):
            findings.append(ctx.finding(
                "clock", "VCP001", node,
                f"wall-clock read time.{fn.attr}() — serving-stack code "
                f"runs on the virtual clock; sanctioned wall-measured "
                f"engine-stage sites belong in the baseline allowlist"))
        elif isinstance(fn, ast.Name) and fn.id in from_time:
            findings.append(ctx.finding(
                "clock", "VCP001", node,
                f"wall-clock read {fn.id}() (imported from time)"))
        # datetime.now() family: datetime.datetime.now(), datetime.now()
        elif (isinstance(fn, ast.Attribute) and fn.attr in DATETIME_NOW_FNS
              and _mentions_datetime(fn.value, dt_aliases, from_dt)):
            findings.append(ctx.finding(
                "clock", "VCP001", node,
                f"wall-clock read datetime .{fn.attr}()"))
        # random.<draw>() on the module-global RNG
        elif (isinstance(fn, ast.Attribute)
              and isinstance(fn.value, ast.Name)
              and fn.value.id in random_aliases
              and fn.attr not in RANDOM_NON_GLOBAL):
            findings.append(ctx.finding(
                "clock", "VCP002", node,
                f"module-global RNG random.{fn.attr}() — unseeded and "
                f"process-wide; use random.Random(seed)"))
        # random.Random() with no seed
        elif (isinstance(fn, ast.Attribute) and fn.attr == "Random"
              and isinstance(fn.value, ast.Name)
              and fn.value.id in random_aliases
              and not node.args and not node.keywords):
            findings.append(ctx.finding(
                "clock", "VCP002", node,
                "unseeded random.Random() — pass an explicit seed"))
        # np.random.*
        elif (isinstance(fn, ast.Attribute)
              and isinstance(fn.value, ast.Attribute)
              and fn.value.attr == "random"
              and isinstance(fn.value.value, ast.Name)
              and fn.value.value.id in np_aliases):
            if fn.attr in ("default_rng", "Generator", "RandomState"):
                if not node.args and not node.keywords:
                    findings.append(ctx.finding(
                        "clock", "VCP002", node,
                        f"unseeded np.random.{fn.attr}() — pass an "
                        f"explicit seed"))
            else:
                findings.append(ctx.finding(
                    "clock", "VCP002", node,
                    f"global-state np.random.{fn.attr}() — use "
                    f"np.random.default_rng(seed)"))
    return findings


def _mentions_datetime(value: ast.AST, dt_aliases, from_dt) -> bool:
    # datetime.datetime.now() -> Attribute(datetime, 'datetime').now
    if isinstance(value, ast.Attribute):
        return (value.attr in ("datetime", "date")
                and isinstance(value.value, ast.Name)
                and value.value.id in dt_aliases)
    # datetime.now() with `from datetime import datetime`
    return isinstance(value, ast.Name) and value.id in from_dt
