"""Pass 5a — exception-swallowing hygiene (EXC).

Drop handling on the serving hot path is *accounted*: every failed
request must land in exactly one ``drops_by_reason`` bucket, chaos
faults must surface as reason codes, and the recovery ledger asserts
exactly-once outcomes.  A broad ``except`` that swallows the exception
erases the reason code before accounting sees it.  Codes:

* ``EXC001`` — bare ``except:`` (also catches ``KeyboardInterrupt`` /
  ``SystemExit``); never acceptable.
* ``EXC002`` — broad ``except Exception`` / ``except BaseException``
  that neither re-raises nor uses the bound exception — the error is
  silently discarded.
* ``EXC003`` — any broad except on a hot-path module (``router/``,
  ``serving/``, ``obs/``, ``runtime/serve.py``, ``runtime/paging.py``):
  these paths must catch specific exception types so reason codes stay
  precise.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import FileContext, Finding, file_pass

BROAD = frozenset({"Exception", "BaseException"})

HOT_PREFIXES = (
    "src/repro/router/",
    "src/repro/serving/",
    "src/repro/obs/",
)
HOT_FILES = frozenset({
    "src/repro/runtime/serve.py",
    "src/repro/runtime/paging.py",
})


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if isinstance(t, ast.Name) and t.id in BROAD:
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name) and e.id in BROAD
                   for e in t.elts)
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _uses_binding(handler: ast.ExceptHandler) -> bool:
    if handler.name is None:
        return False
    return any(isinstance(n, ast.Name) and n.id == handler.name
               and isinstance(n.ctx, ast.Load)
               for n in ast.walk(handler))


@file_pass("exc")
def exc_pass(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    hot = ctx.rel in HOT_FILES or ctx.rel.startswith(HOT_PREFIXES)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(ctx.finding(
                "exc", "EXC001", node,
                "bare `except:` — catches KeyboardInterrupt/SystemExit "
                "and erases the failure reason; name the exception types"))
            continue
        if not _is_broad(node):
            continue
        if hot:
            findings.append(ctx.finding(
                "exc", "EXC003", node,
                "broad `except Exception` on a serving hot path — drop "
                "accounting needs precise reason codes; catch the "
                "specific exception types (or route through a reason "
                "code before discarding)"))
        elif not (_reraises(node) or _uses_binding(node)):
            findings.append(ctx.finding(
                "exc", "EXC002", node,
                "broad except swallows the exception without using or "
                "re-raising it — the failure reason is lost; log it, "
                "re-raise, or narrow the type"))
    return findings
