"""Orbit power model: sunlit/eclipse phases and the global energy bucket.

A spacecraft's electrical budget is an *orbit-average* constraint: the
solar array harvests during sunlit phases, the battery rides through
eclipse, and the payload must shape its load so the battery never runs
dry.  Two pieces model that here, entirely on the fleet's deterministic
virtual clock (no wall time — the same profile prices a test, a
benchmark, and a launcher run identically):

* :class:`PowerProfile` — a cyclic sequence of :class:`OrbitPhase`
  entries (name, duration, harvested watts).  ``energy_between(t0, t1)``
  integrates the harvest over any interval, handling partial phases and
  whole orbits in O(phases).
* :class:`EnergyBucket` — the battery as a token bucket: ``advance(now)``
  banks the profile's harvest since the last call (clipped at capacity),
  ``drain(j)`` spends against it (clipped at zero — the level is never
  negative; spend beyond the level is recorded as ``shortfall_j``, the
  quantity an uncontrolled fleet would have overdrawn).

The :class:`~repro.orbit.controller.FleetController` drains the bucket
with the fleet's telemetry ``energy_j`` deltas and keys its dispatch
mode off ``frac`` — that is the whole coupling between orbit mechanics
and the router.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple


@dataclass(frozen=True)
class OrbitPhase:
    """One leg of the orbit's power cycle."""
    name: str
    duration_s: float
    power_w: float

    def __post_init__(self):
        if self.duration_s <= 0:
            raise ValueError(f"phase {self.name!r}: duration must be > 0")
        if self.power_w < 0:
            raise ValueError(f"phase {self.name!r}: power must be >= 0")


class PowerProfile:
    """Cyclic harvested-power profile on the fleet's virtual clock."""

    def __init__(self, phases: Sequence[OrbitPhase]):
        if not phases:
            raise ValueError("a power profile needs at least one phase")
        self.phases: Tuple[OrbitPhase, ...] = tuple(phases)
        self.period_s = sum(p.duration_s for p in self.phases)
        # prefix sums: phase start offsets and cumulative energy, so any
        # within-cycle integral is two lookups + one partial phase
        self._starts = [0.0]
        self._cum_j = [0.0]
        for p in self.phases:
            self._starts.append(self._starts[-1] + p.duration_s)
            self._cum_j.append(self._cum_j[-1] + p.duration_s * p.power_w)
        self.cycle_j = self._cum_j[-1]

    @property
    def orbit_average_w(self) -> float:
        """The long-run harvest rate — the fleet's sustainable draw."""
        return self.cycle_j / self.period_s

    def phase_at(self, t: float) -> OrbitPhase:
        tau = t % self.period_s
        for start, p in zip(self._starts, self.phases):
            if tau < start + p.duration_s:
                return p
        return self.phases[-1]          # tau == period_s boundary

    def power_at(self, t: float) -> float:
        return self.phase_at(t).power_w

    def _cycle_energy_to(self, tau: float) -> float:
        """Integral of power over [0, tau] for tau within one period."""
        for i, p in enumerate(self.phases):
            if tau <= self._starts[i + 1]:
                return self._cum_j[i] + (tau - self._starts[i]) * p.power_w
        return self.cycle_j

    def energy_between(self, t0: float, t1: float) -> float:
        """Harvested joules over [t0, t1] (t1 >= t0)."""
        if t1 <= t0:
            return 0.0
        cycles, tau0 = divmod(t0, self.period_s)
        span = t1 - t0
        full, rem = divmod(span, self.period_s)
        e = full * self.cycle_j
        tau1 = tau0 + rem
        if tau1 <= self.period_s:
            e += self._cycle_energy_to(tau1) - self._cycle_energy_to(tau0)
        else:
            e += (self.cycle_j - self._cycle_energy_to(tau0)
                  + self._cycle_energy_to(tau1 - self.period_s))
        return e


class EnergyBucket:
    """The battery as a token bucket over the fleet's energy telemetry.

    Invariant (property-tested): ``0 <= level_j <= capacity_j`` after any
    interleaving of ``advance`` and ``drain`` calls.  Harvest beyond a
    full bucket is counted as ``wasted_j``; spend beyond the level is
    counted as ``shortfall_j`` (the overdraw an uncapped fleet would have
    taken out of the orbit-average budget).
    """

    def __init__(self, capacity_j: float, profile: PowerProfile = None,
                 level_j: float = None, t0: float = 0.0):
        if capacity_j <= 0:
            raise ValueError("bucket capacity must be > 0")
        self.capacity_j = capacity_j
        self.profile = profile
        self.level_j = (capacity_j if level_j is None
                        else min(max(level_j, 0.0), capacity_j))
        self._t = t0
        self.harvested_j = 0.0          # raw profile integral seen so far
        self.wasted_j = 0.0             # harvest clipped by a full bucket
        self.spent_j = 0.0              # total drain requested
        self.shortfall_j = 0.0          # drain requested beyond the level

    @property
    def frac(self) -> float:
        return self.level_j / self.capacity_j

    def rebase(self, now: float) -> None:
        """Move the harvest clock forward to ``now`` without banking the
        skipped interval — attaching a controller to a fleet that has
        already been running must not credit phantom pre-attach
        harvest."""
        self._t = max(self._t, now)

    def advance(self, now: float) -> float:
        """Bank the profile's harvest since the last call; returns the
        joules actually added (post-clip)."""
        if self.profile is None or now <= self._t:
            return 0.0
        e = self.profile.energy_between(self._t, now)
        self._t = now
        take = min(e, self.capacity_j - self.level_j)
        self.level_j += take
        self.harvested_j += e
        self.wasted_j += e - take
        return take

    def drain(self, joules: float) -> float:
        """Spend against the bucket; returns the joules actually covered.
        The level clips at zero — never negative."""
        if joules <= 0:
            return 0.0
        take = min(joules, self.level_j)
        self.level_j -= take
        self.spent_j += joules
        self.shortfall_j += joules - take
        return take

    def refund(self, joules: float) -> float:
        """Return an earlier (estimated) drain to the bucket; returns the
        joules actually restored.  The level clips at capacity — a refund
        can never mint energy the battery cannot hold — and ``spent_j``
        is credited back so admission prepay + completion reconcile nets
        to the real metered spend."""
        if joules <= 0:
            return 0.0
        take = min(joules, self.capacity_j - self.level_j)
        self.level_j += take
        self.spent_j -= take
        return take

    def summary(self) -> dict:
        return {"capacity_j": round(self.capacity_j, 6),
                "level_j": round(self.level_j, 6),
                "frac": round(self.frac, 4),
                "harvested_j": round(self.harvested_j, 6),
                "wasted_j": round(self.wasted_j, 6),
                "spent_j": round(self.spent_j, 6),
                "shortfall_j": round(self.shortfall_j, 6)}


def budget_j(profile: PowerProfile, initial_level_j: float,
             t0: float, t1: float) -> float:
    """The orbit-average energy budget over [t0, t1]: what the battery
    held at t0 plus everything the profile harvests by t1.  A capped
    fleet's cumulative ``energy_j`` must stay within this (plus in-flight
    slack); an uncapped one is free to overshoot it."""
    return initial_level_j + profile.energy_between(t0, t1)
