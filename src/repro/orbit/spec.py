"""OrbitSpec — the orbit control plane as data, like FleetSpec.

An :class:`OrbitSpec` declares everything the fleet controller needs —
the cyclic power profile (:class:`PhaseSpec` per sunlit/eclipse leg),
the battery/bucket size and initial charge, the mode thresholds, which
SLO priorities may be deferred, and (optionally) a
:class:`~repro.orbit.autoscale.ScalingPolicy`.  ``to_dict`` /
``from_dict`` round-trip losslessly through JSON, mirroring
``FleetSpec``, so a launcher flag set, a benchmark scenario, and a test
fixture share one config path for the control plane too.

``attach(client)`` builds the live controller onto an existing
:class:`~repro.serving.client.ServingClient`::

    client = fleet_spec.build()
    ctrl = OrbitSpec(phases=[PhaseSpec("sunlit", 60.0, 8.0),
                             PhaseSpec("eclipse", 35.0, 1.0)],
                     bucket_j=120.0).attach(client)
    ...                                  # submit / open_loop as usual
    print(ctrl.report())
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

from repro.orbit.autoscale import Autoscaler, ScalingPolicy
from repro.orbit.controller import FleetController
from repro.orbit.power import EnergyBucket, OrbitPhase, PowerProfile


@dataclass
class PhaseSpec:
    """One orbit phase as data (sunlit/eclipse leg of the power cycle)."""
    name: str
    duration_s: float
    power_w: float

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "PhaseSpec":
        unknown = sorted(set(d) - {"name", "duration_s", "power_w"})
        if unknown:
            raise ValueError(
                f"PhaseSpec.from_dict: unknown key(s) {unknown}; valid "
                f"keys are ['duration_s', 'name', 'power_w']")
        return cls(**d)


@dataclass
class OrbitSpec:
    """The orbit control plane as data; ``attach()`` makes it live."""
    phases: List[PhaseSpec]
    bucket_j: float                       # battery capacity
    initial_frac: float = 1.0             # charge at t=0
    conserve_frac: float = 0.5            # below -> prefer cheap plans, defer
    critical_frac: float = 0.15           # below -> reject as a last resort
    hysteresis_frac: float = 0.05         # extra charge needed to mode-up
    defer_max_priority: int = 0           # SLO priority <= this is deferrable
    scaling: Optional[ScalingPolicy] = None
    # radiation-storm response: the controller keeps a leaky integrator
    # of hardening events (retries, watchdog trips, detected bitflips,
    # quarantined blocks, replayed handoffs).  While the pressure is at
    # or above ``storm_events`` the dispatch mode floors at "conserve" —
    # cheap plans, deferred background work, no scale-ups — even on a
    # full battery: a storm spikes the retry bill, so spending the
    # margin on throughput invites the next upset to waste it.  0
    # disables the ladder.
    storm_events: int = 0                 # pressure threshold; 0 -> off
    storm_decay: float = 0.9              # per-tick integrator decay

    def __post_init__(self):
        if not 0.0 <= self.critical_frac <= self.conserve_frac <= 1.0:
            raise ValueError(
                "need 0 <= critical_frac <= conserve_frac <= 1, got "
                f"{self.critical_frac} / {self.conserve_frac}")
        if self.hysteresis_frac < 0.0:
            raise ValueError("hysteresis_frac must be >= 0")
        if not 0.0 <= self.storm_decay < 1.0:
            raise ValueError("storm_decay must be in [0, 1)")

    # ------------------------------------------------------------------
    # serialization (JSON round-trip, like FleetSpec)
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "phases": [p.to_dict() for p in self.phases],
            "bucket_j": self.bucket_j,
            "initial_frac": self.initial_frac,
            "conserve_frac": self.conserve_frac,
            "critical_frac": self.critical_frac,
            "hysteresis_frac": self.hysteresis_frac,
            "defer_max_priority": self.defer_max_priority,
            "scaling": (None if self.scaling is None
                        else self.scaling.to_dict()),
            "storm_events": self.storm_events,
            "storm_decay": self.storm_decay,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "OrbitSpec":
        d = dict(d)
        valid = {f for f in cls.__dataclass_fields__}
        unknown = sorted(set(d) - valid)
        if unknown:
            raise ValueError(
                f"OrbitSpec.from_dict: unknown key(s) {unknown}; valid "
                f"keys are {sorted(valid)}")
        d["phases"] = [PhaseSpec.from_dict(p) for p in d["phases"]]
        sc = d.get("scaling")
        d["scaling"] = None if sc is None else ScalingPolicy.from_dict(sc)
        return cls(**d)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "OrbitSpec":
        """Fail fast before the controller goes live.  ``__post_init__``
        already pins the mode-threshold ordering; this checks the parts
        a JSON round-trip can still get wrong — the power profile and
        battery sizing.  Called by ``attach()``."""
        if not self.phases:
            raise ValueError("OrbitSpec needs at least one PhaseSpec — "
                             "the power profile cannot be empty")
        for p in self.phases:
            if p.duration_s <= 0:
                raise ValueError(f"phase {p.name!r}: duration_s must be "
                                 f"> 0 (got {p.duration_s})")
            if p.power_w < 0:
                raise ValueError(f"phase {p.name!r}: power_w must be "
                                 f">= 0 (got {p.power_w})")
        if self.bucket_j <= 0:
            raise ValueError(f"bucket_j must be > 0 (got {self.bucket_j})")
        if not 0.0 <= self.initial_frac <= 1.0:
            raise ValueError(f"initial_frac must be in [0, 1] "
                             f"(got {self.initial_frac})")
        if self.storm_events < 0:
            raise ValueError(f"storm_events must be >= 0 "
                             f"(got {self.storm_events})")
        return self

    # ------------------------------------------------------------------
    # assembly
    # ------------------------------------------------------------------
    def profile(self) -> PowerProfile:
        return PowerProfile([OrbitPhase(p.name, p.duration_s, p.power_w)
                             for p in self.phases])

    def bucket(self) -> EnergyBucket:
        return EnergyBucket(self.bucket_j, self.profile(),
                            level_j=self.initial_frac * self.bucket_j)

    def attach(self, client, template=None) -> FleetController:
        """Build the live controller onto a ServingClient.

        ``template`` — the :class:`~repro.serving.spec.PoolSpec` the
        autoscaler clones; defaults to the entry in the client's
        ``FleetSpec`` whose name matches ``scaling.template``.
        """
        self.validate()
        scaler = None
        if self.scaling is not None:
            if template is None:
                pools = [] if client.spec is None else client.spec.pools
                match = [p for p in pools if p.name == self.scaling.template]
                if not match:
                    raise ValueError(
                        f"scaling template {self.scaling.template!r} not "
                        f"found in the client's FleetSpec; pass template=")
                template = match[0]
            scaler = Autoscaler(self.scaling, template)
        return FleetController(client, self.bucket(), self,
                               autoscaler=scaler)
