"""repro.orbit — orbit-aware fleet control over the serving data plane.

MPAI's premise is power-efficient *on-board* inference: the fleet rides
speed-accuracy-energy trade-offs under a hard spacecraft power envelope,
not just per-request SLOs.  ``repro.serving`` is the data plane (specs,
router, engines, streams); this package is the control plane that makes
the fleet track the orbit instead of being provisioned for peak:

* a **global energy token bucket** (``power.py``) refilled by a cyclic
  sunlit/eclipse :class:`PowerProfile` on the fleet's deterministic
  virtual clock and drained by the pools' telemetry ``energy_j``;
* an **energy-aware dispatch mode**: as the bucket empties the router
  flips to energy-first plan selection, offline-class work is deferred
  until sunlight returns, and only a critical-mode dry bucket rejects;
* a **telemetry-driven autoscaler** (``autoscale.py``) that grows and
  shrinks the fleet live — queue depth, OutOfBlocks backpressure, and
  SLO violations trigger ``ServingClient.add_pool`` /
  ``retire_pool`` / ``set_capacity``; retirements drain gracefully and
  never drop an in-flight stream;
* an :class:`OrbitSpec` (``spec.py``) declaring all of it as
  JSON-round-trippable data, and a :class:`FleetController`
  (``controller.py``) ``step()`` loop the ServingClient clock drives
  automatically.

Quickstart::

    from repro.orbit import OrbitSpec, PhaseSpec, ScalingPolicy

    client = fleet_spec.build()                  # the PR-3 data plane
    ctrl = OrbitSpec(
        phases=[PhaseSpec("sunlit", 60.0, 8.0),  # harvest 8 W for 60 s
                PhaseSpec("eclipse", 35.0, 1.0)],
        bucket_j=120.0,
        scaling=ScalingPolicy(template="lm", max_pools=3),
    ).attach(client)
    ...                                          # submit / open_loop as usual
    print(ctrl.report())                         # mode, bucket, scale actions

Demo: ``PYTHONPATH=src python -m repro.launch.orbit``.
Bench: ``PYTHONPATH=src python -m benchmarks.orbit_bench``.
"""
from repro.orbit.autoscale import Autoscaler, ScalingPolicy
from repro.orbit.controller import MODES, FleetController
from repro.orbit.power import (EnergyBucket, OrbitPhase, PowerProfile,
                               budget_j)
from repro.orbit.spec import OrbitSpec, PhaseSpec

__all__ = [
    "Autoscaler", "EnergyBucket", "FleetController", "MODES", "OrbitPhase",
    "OrbitSpec", "PhaseSpec", "PowerProfile", "ScalingPolicy", "budget_j",
]
