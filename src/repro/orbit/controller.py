"""The closed control loop over the serving fleet: energy cap + scaling.

:class:`FleetController` runs beside the router on the fleet's virtual
clock (the :class:`~repro.serving.client.ServingClient` steps it from
``advance()``, so ``result()``/``stream()``/``drain()``/``open_loop``
all drive it for free).  Each tick it:

1. **banks and drains the energy bucket** — harvest from the orbit
   power profile since the last tick in, the fleet's telemetry
   ``energy_j`` delta out;
2. **derives the dispatch mode** from the bucket level::

       frac > conserve_frac                ->  "nominal"
       critical_frac < frac <= conserve    ->  "conserve"
       frac <= critical_frac               ->  "critical"

   and mirrors it onto ``Router.energy_mode`` so plan selection flips
   from latency-slack-first to energy-first;
3. **applies the admission policy** (consulted by ``ServingClient.submit``
   *before* the router sees a request): in nominal mode everything
   dispatches; below it, deferrable work (SLO priority at or below
   ``defer_max_priority`` — the offline/background classes) parks in the
   deferral queue instead of draining the battery; non-deferrable work
   still dispatches on the energy-first frontier, and is rejected only
   as a last resort — critical mode with a bone-dry bucket;
4. **releases the deferral queue** when the mode recovers to nominal
   (requests keep their original arrival time, so the latency-for-energy
   trade is recorded honestly as end-to-end latency / violations);
5. **steps the autoscaler**, if the spec declared one.

Degradation order under a shrinking bucket is therefore: cheaper plans
-> deferred offline work -> rejection, matching MPAI's premise that an
onboard fleet rides the speed-accuracy-energy frontier under a hard
power envelope rather than dropping work at the first brown-out.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.orbit.autoscale import Autoscaler
from repro.orbit.power import EnergyBucket

MODES = ("nominal", "conserve", "critical")


class FleetController:
    """One instance per ServingClient; built by ``OrbitSpec.attach``."""

    def __init__(self, client, bucket: EnergyBucket, spec,
                 autoscaler: Optional[Autoscaler] = None):
        self.client = client
        self.bucket = bucket
        self.spec = spec                       # OrbitSpec (thresholds)
        self.autoscaler = autoscaler
        self.mode = "nominal"
        self.deferred: List = []               # parked RouterRequests
        self.transitions: List[Tuple[float, str]] = []
        # radiation-storm pressure: leaky integrator of hardening-event
        # deltas (retries + watchdog trips + bitflips + quarantines +
        # handoff replays); at/above spec.storm_events the mode floors
        # at "conserve"
        self.storm_pressure = 0.0
        self._seen_events = self._hardening_events()
        self._seen_j = self._fleet_energy_j()
        # admission-time energy prepay: rid -> estimated joules drained
        # at submit, refunded when the request settles (the real metered
        # spend drains through the telemetry delta path as usual, so
        # prepay + reconcile nets to the actual draw)
        self._prepaid: Dict[int, float] = {}
        self.initial_level_j = bucket.level_j
        bucket.rebase(client.now)              # no phantom pre-attach harvest
        client.attach_controller(self)
        self._set_mode(client.now)             # honor the initial level

    # ------------------------------------------------------------------
    # signals
    # ------------------------------------------------------------------
    def _fleet_energy_j(self) -> float:
        """Cumulative fleet spend: retired pools keep their counters in
        telemetry, so this is monotone across scale-downs."""
        return sum(c.energy_j
                   for c in self.client.router.telemetry.pools.values())

    def _hardening_events(self) -> int:
        """Cumulative count of every hardening event the fleet has
        recorded — the storm ladder's raw signal.  Fired SLO pages join
        it: a burning error budget is evidence of environmental stress
        exactly like retries and bitflips are."""
        t = self.client.router.telemetry
        return (t.retries + t.watchdog_trips + t.alerts.pages_fired
                + sum(c.bitflips_detected + c.blocks_quarantined
                      + c.watchdog_trips + c.handoffs_replayed
                      for c in t.pools.values()))

    @property
    def storm(self) -> bool:
        return (self.spec.storm_events > 0
                and self.storm_pressure >= self.spec.storm_events)

    @property
    def deferred_count(self) -> int:
        return len(self.deferred)

    # ------------------------------------------------------------------
    # admission policy (consulted by ServingClient.submit)
    # ------------------------------------------------------------------
    def deferrable(self, slo) -> bool:
        return slo.priority <= self.spec.defer_max_priority

    def admission(self, req) -> str:
        """One of "dispatch" | "defer" | "reject" for a fresh request."""
        if self.mode == "nominal":
            return "dispatch"
        if self.deferrable(req.slo):
            return "defer"
        if self.mode == "critical" and self.bucket.level_j <= 0.0:
            return "reject"                    # last resort: battery dry
        return "dispatch"

    def prepay(self, req, tokens: Optional[int]) -> None:
        """Charge a freshly dispatched request's *expected* plan energy
        against the bucket so the controller sees load the moment it is
        admitted, not ``pool_latency_s`` later when its tokens decode.
        The estimate is the optimistic energy floor (the frontier's
        cheapest plan per token — the same floor ``_release`` meters
        against) times the declared token budget, capped at the current
        level so an estimate never manufactures shortfall the fleet
        would not really incur.  ``step()`` refunds the full prepay when
        the request settles; the real spend drains via the telemetry
        ``energy_j`` delta, so the bucket nets to the metered draw."""
        if not tokens or tokens <= 0:
            return
        floor = min((p.energy_j for p in self.client.router.frontier),
                    default=0.0)
        est = min(floor * tokens, self.bucket.level_j)
        if est <= 0.0:
            return
        self.bucket.drain(est)
        self._prepaid[req.rid] = est
        self._set_mode(self.client.now)

    def defer(self, req, now: Optional[float] = None) -> None:
        req.deferred = True
        self.deferred.append(req)
        self.client.router.telemetry.energy_deferred += 1
        self.client.router.telemetry.tracer.begin(
            req.rid, "defer", self.client.now if now is None else now,
            mode=self.mode)

    # ------------------------------------------------------------------
    # control step (called from ServingClient.advance every tick)
    # ------------------------------------------------------------------
    def step(self, now: float) -> None:
        self.bucket.advance(now)
        if self._prepaid:
            # reconcile settled requests: hand the admission-time
            # estimate back (the real spend has drained — or is about
            # to — through the telemetry delta below)
            handles = self.client._handles
            for rid in [r for r in self._prepaid
                        if (h := handles.get(r)) is None or h.done]:
                self.bucket.refund(self._prepaid.pop(rid))
        spent = self._fleet_energy_j()
        if spent > self._seen_j:               # drain against real work
            self.bucket.drain(spent - self._seen_j)
            self._seen_j = spent
        if self.spec.storm_events > 0:
            ev = self._hardening_events()
            self.storm_pressure = (self.storm_pressure
                                   * self.spec.storm_decay
                                   + (ev - self._seen_events))
            self._seen_events = ev
        self._set_mode(now)
        if self.mode == "nominal" and self.deferred:
            self._release(now)
        if self.autoscaler is not None:
            # never retire capacity while any SLO alert fires: scale-down
            # during a burn converts a latency regression into a spiral
            hold = self.client.router.telemetry.alerts.firing_count > 0
            self.autoscaler.step(self.client, now, mode=self.mode,
                                 hold_scale_down=hold)

    def _set_mode(self, now: float) -> None:
        """Threshold the bucket level with hysteresis: dropping a mode
        happens at the threshold, climbing back requires an extra
        ``hysteresis_frac`` of charge — so the mode doesn't chatter while
        the level rides a boundary."""
        f = self.bucket.frac
        crit, cons = self.spec.critical_frac, self.spec.conserve_frac
        h = self.spec.hysteresis_frac
        if f <= crit or (self.mode == "critical" and f <= crit + h):
            mode = "critical"
        elif f <= cons or (self.mode != "nominal" and f <= cons + h):
            mode = "conserve"
        else:
            mode = "nominal"
        paging = self.client.router.telemetry.alerts.paging
        if mode == "nominal" and (self.storm or paging):
            # storm ladder / SLO burn: retry pressure or a firing page
            # alert floors the mode at conserve even on a healthy
            # battery (scale-ups are suppressed for free — the
            # autoscaler already gates on mode)
            mode = "conserve"
        if mode != self.mode or not self.transitions:
            self.mode = mode
            self.transitions.append((round(now, 4), mode))
            self.client.router.telemetry.tracer.event(
                "mode", now, mode=mode, bucket_frac=round(f, 4),
                storm=self.storm, paging=paging)
        self.client.router.energy_mode = ("nominal" if mode == "nominal"
                                          else "conserve")

    def _release(self, now: float) -> None:
        """Sunlight is back: dispatch parked work, oldest first — but
        *metered* against the bucket's headroom above the conserve
        threshold.  Releasing the whole eclipse backlog in one burst
        would drain the battery straight back below the threshold and
        overshoot the orbit budget; instead each released request is
        charged its optimistic energy floor (the frontier's cheapest
        plan) against the headroom, so the backlog drains at the rate
        sunlight actually funds.  A release the router now rejects (load
        estimate) is surfaced on the handle like any admission-time
        rejection."""
        router = self.client.router
        floor = min((p.energy_j for p in router.frontier), default=0.0)
        headroom = (self.bucket.level_j
                    - self.spec.conserve_frac * self.bucket.capacity_j)
        while self.deferred and headroom > 0.0:
            req = self.deferred.pop(0)
            req.deferred = False
            # a failed release ends the chain via the router's rejection
            # path, which also sweeps up a still-open defer span
            router.telemetry.tracer.finish(req.rid, "defer", now)
            ok = router.submit(req, now)
            handle = self.client._handles.get(req.rid)
            if handle is not None:
                handle.admitted = ok
            headroom -= max(floor, 1e-12)  # floor=0 still makes progress

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def report(self) -> Dict:
        return {
            "mode": self.mode,
            "deferred_waiting": self.deferred_count,
            "storm_pressure": round(self.storm_pressure, 4),
            # expected-energy prepays still outstanding (admitted work
            # whose tokens have not finished decoding)
            "prepaid_j": round(sum(self._prepaid.values()), 6),
            "alerts": self.client.router.telemetry.alerts.snapshot(),
            "bucket": self.bucket.summary(),
            # per-pool spend the bucket drained against — disaggregated
            # pools show their co-processing split here (the `.prefill`
            # stage pool is charged separately from its decode pool)
            "energy_by_pool": {
                name: round(c.energy_j, 4) for name, c in
                sorted(self.client.router.telemetry.pools.items())},
            "transitions": [{"t": t, "mode": m}
                            for t, m in self.transitions],
            "scale_actions": ([] if self.autoscaler is None
                              else list(self.autoscaler.actions)),
            # what the fleet looked like DURING the orbit, not just at
            # its end: the ring-buffered per-tick samples' roll-up
            "timeseries": self.client.timeseries.summary(),
        }
