"""Telemetry-driven autoscaler: grow/shrink the fleet from live signals.

The router dispatches over whatever pools exist; this module decides how
many should exist.  An :class:`Autoscaler` watches one *family* of pools
— a template :class:`~repro.serving.spec.PoolSpec` plus the clones it
has spawned (named ``<template>/as<k>``) — and reacts to three
telemetry signals:

* **queue depth**: any family pool's live ``load`` at or above
  ``queue_high`` means the family is saturated — add a clone (or raise
  the template's ``capacity``, in ``grow="capacity"`` mode);
* **backpressure**: new engine ``OutOfBlocks`` deferrals since the last
  look mean the KV pool itself is the bottleneck — same response;
* **violations**: new fleet SLO violations mean provisioned capacity is
  already costing deadlines — same response.

Shrink is the mirror image: when the family's total load falls to
``queue_low`` the newest clone is retired *gracefully* — the pool stops
taking new dispatches, finishes everything queued and in flight (no
stream is ever dropped), and is removed once drained
(:meth:`~repro.serving.client.ServingClient.retire_pool`).

Orbit awareness: scale-up is suppressed outside ``"nominal"`` energy
mode — spinning up capacity during an eclipse would spend the battery
faster exactly when the controller is trying to conserve it.  Scale-down
runs in any mode.

All decisions run on the fleet's virtual clock with a ``cooldown_s``
between actions, so scaling is deterministic for a given trace.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, replace
from typing import Dict, List, Optional


@dataclass
class ScalingPolicy:
    """Declarative scaling rules for one pool family (JSON-round-trips
    inside :class:`~repro.orbit.spec.OrbitSpec`)."""
    template: str                    # PoolSpec name to clone / resize
    min_pools: int = 1
    max_pools: int = 3
    queue_high: int = 8              # per-pool load that triggers growth
    queue_low: int = 0               # family load at/below which we shrink
    cooldown_s: float = 0.25         # virtual seconds between actions
    grow: str = "pools"              # "pools" -> clone | "capacity" -> resize
    capacity_step: int = 1
    min_capacity: int = 1
    max_capacity: int = 8

    def __post_init__(self):
        if self.grow not in ("pools", "capacity"):
            raise ValueError(f"unknown grow mode {self.grow!r}")
        if self.min_pools < 1:
            raise ValueError("min_pools must keep at least one pool")
        if self.max_pools < self.min_pools:
            raise ValueError(f"max_pools {self.max_pools} < min_pools "
                             f"{self.min_pools}")
        if self.queue_low >= self.queue_high:
            # overlapping grow/shrink bands would oscillate add/retire
            # every cooldown at steady load
            raise ValueError(f"queue_low ({self.queue_low}) must be below "
                             f"queue_high ({self.queue_high})")
        if not 1 <= self.min_capacity <= self.max_capacity:
            raise ValueError(f"need 1 <= min_capacity <= max_capacity, "
                             f"got {self.min_capacity}/{self.max_capacity}")
        if self.capacity_step < 1:
            raise ValueError("capacity_step must be >= 1")

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ScalingPolicy":
        return cls(**d)


class Autoscaler:
    """Apply a :class:`ScalingPolicy` to a live fleet.

    ``template_spec`` is the :class:`~repro.serving.spec.PoolSpec` clones
    are built from; clone names get a monotonically increasing suffix so
    a retired name is never reused (pool telemetry history stays
    unambiguous).
    """

    def __init__(self, policy: ScalingPolicy, template_spec):
        if template_spec.name != policy.template:
            raise ValueError(
                f"template spec {template_spec.name!r} does not match "
                f"policy template {policy.template!r}")
        self.policy = policy
        self.template_spec = template_spec
        self.actions: List[Dict] = []        # applied, for reports/tests
        self._seq = 0
        self._last_action_s = -math.inf
        self._deferrals_seen = 0
        self._violations_seen = 0
        self._pressure_latch = False         # edge signals held until usable

    # ------------------------------------------------------------------
    # signals
    # ------------------------------------------------------------------
    def family(self, client) -> List[str]:
        prefix = self.policy.template + "/as"
        return [n for n in client.router.pools
                if n == self.policy.template or n.startswith(prefix)]

    def _pressure(self, client, pools) -> bool:
        """Saturation from queue depth, backpressure, or violations.
        The deferral/violation deltas are edge-triggered, so they latch:
        a burst recorded while growth is suppressed (eclipse mode) still
        counts once growth is allowed again.  The latch clears when the
        family's backlog falls to ``queue_low`` — a violation recorded as
        the last late batch *completes* must not grow a fleet that is
        already idle."""
        tel = client.router.telemetry
        deferrals = sum(tel.pools[p.name].deferrals for p in pools)
        if (deferrals > self._deferrals_seen
                or tel.violations > self._violations_seen):
            self._pressure_latch = True
        self._deferrals_seen = deferrals
        self._violations_seen = tel.violations
        if sum(p.load for p in pools) <= self.policy.queue_low:
            self._pressure_latch = False     # pressure resolved itself
        return (max(p.load for p in pools) >= self.policy.queue_high
                or self._pressure_latch)

    # ------------------------------------------------------------------
    # control step
    # ------------------------------------------------------------------
    def step(self, client, now: float, mode: str = "nominal",
             hold_scale_down: bool = False) -> Optional[Dict]:
        """Evaluate the policy once; apply and return at most one action
        (None when nothing fires).  Called by the FleetController on the
        fleet clock.  ``hold_scale_down`` suppresses the shrink branches
        (the controller sets it while any SLO alert fires — retiring
        capacity mid-burn would feed the regression it alerts on)."""
        p = self.policy
        if now - self._last_action_s < p.cooldown_s:
            return None
        live = client.router.pools
        pools = [live[n] for n in self.family(client) if not live[n].draining]
        if not pools:
            return None
        hot = self._pressure(client, pools)
        idle = sum(f.load for f in pools) <= p.queue_low
        act = None
        if p.grow == "capacity":
            base = live.get(p.template)
            if base is None:
                return None
            if hot and mode == "nominal" and base.capacity < p.max_capacity:
                cap = min(p.max_capacity, base.capacity + p.capacity_step)
                client.set_capacity(p.template, cap)
                act = {"op": "set_capacity", "pool": p.template,
                       "capacity": cap, "t": round(now, 4)}
            elif idle and not hold_scale_down \
                    and base.capacity > p.min_capacity:
                cap = max(p.min_capacity, base.capacity - p.capacity_step)
                client.set_capacity(p.template, cap)
                act = {"op": "set_capacity", "pool": p.template,
                       "capacity": cap, "t": round(now, 4)}
        else:
            if hot and mode == "nominal" and len(pools) < p.max_pools:
                name = f"{p.template}/as{self._seq}"
                self._seq += 1
                client.add_pool(replace(self.template_spec, name=name))
                act = {"op": "add", "pool": name, "t": round(now, 4)}
            elif idle and not hold_scale_down and len(pools) > p.min_pools:
                clones = [f.name for f in pools
                          if f.name != p.template]
                if clones:
                    victim = clones[-1]          # newest clone drains first
                    client.retire_pool(victim)
                    act = {"op": "retire", "pool": victim,
                           "t": round(now, 4)}
        if act is not None:
            self._last_action_s = now
            self.actions.append(act)
        return act
