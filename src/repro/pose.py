"""UrsoNet training/eval harness on the synthetic pose task — shared by
the Table I benchmark, the pose example, and the QAT-beats-PTQ test.

The four Table I software conditions map to (backbone, head) policies:
  fp32 baseline : (bf16 raw,  fp32 raw)
  int8 PTQ      : (int8 quant, int8 quant)     -- trained fp32, served int8
  int8 QAT      : (int8 fake -> int8 quant, same)
  MPAI          : (int8 fake -> int8 quant, bf16 raw)  -- partition-aware
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy
from repro.data.pipeline import pose_batch
from repro.models.cnn import (UrsoNetConfig, pose_loss, pose_metrics,
                              ursonet_apply, ursonet_init)
from repro.optim import adamw
from repro.configs.base import TrainConfig


def train_ursonet(cfg: UrsoNetConfig,
                  backbone_policy: PrecisionPolicy,
                  head_policy: PrecisionPolicy,
                  steps: int = 200, batch: int = 16, lr: float = 3e-3,
                  seed: int = 0):
    tc = TrainConfig(learning_rate=lr, warmup_steps=10, total_steps=steps,
                     weight_decay=0.0, grad_clip=1.0)
    params = ursonet_init(jax.random.PRNGKey(seed), cfg)
    opt = adamw.init(params)

    def loss_fn(p, images, loc, quat):
        pl, pq = ursonet_apply(p, cfg, images, backbone_policy, head_policy)
        return pose_loss(pl, pq, loc, quat)

    @jax.jit
    def step(p, opt, images, loc, quat):
        l, g = jax.value_and_grad(loss_fn)(p, images, loc, quat)
        p, opt, _ = adamw.apply_updates(p, g, opt, tc)
        return p, opt, l

    history = []
    for s in range(steps):
        b = pose_batch(batch, s, seed=seed, image_hw=cfg.image_hw)
        params, opt, l = step(params, opt, b["images"], b["loc"], b["quat"])
        if s % 25 == 0 or s == steps - 1:
            history.append((s, float(l)))
    return params, history


def eval_ursonet(params, cfg: UrsoNetConfig,
                 backbone_policy: PrecisionPolicy,
                 head_policy: PrecisionPolicy,
                 batches: int = 8, batch: int = 16, seed: int = 1000
                 ) -> Tuple[float, float]:
    """Returns (LOCE meters, ORIE degrees) on held-out batches."""
    fn = jax.jit(lambda p, im: ursonet_apply(p, cfg, im, backbone_policy,
                                             head_policy))
    loces, ories = [], []
    for s in range(batches):
        b = pose_batch(batch, 10_000 + s, seed=seed, image_hw=cfg.image_hw)
        loc, quat = fn(params, b["images"])
        l, o = pose_metrics(loc, quat, b["loc"], b["quat"])
        loces.append(float(l))
        ories.append(float(o))
    return sum(loces) / len(loces), sum(ories) / len(ories)


POLICIES: Dict[str, Tuple[PrecisionPolicy, PrecisionPolicy,
                          PrecisionPolicy, PrecisionPolicy]] = {
    # name: (train_backbone, train_head, serve_backbone, serve_head)
    "fp32": (PrecisionPolicy.bf16(), PrecisionPolicy.fp32(),
             PrecisionPolicy.bf16(), PrecisionPolicy.fp32()),
    "int8_ptq": (PrecisionPolicy.bf16(), PrecisionPolicy.fp32(),
                 PrecisionPolicy.int8(), PrecisionPolicy.int8()),
    "int8_qat": (PrecisionPolicy.int8_qat(), PrecisionPolicy.int8_qat(),
                 PrecisionPolicy.int8(), PrecisionPolicy.int8()),
    "mpai": (PrecisionPolicy.int8_qat(), PrecisionPolicy.bf16(),
             PrecisionPolicy.int8(), PrecisionPolicy.bf16()),
}


def run_condition(name: str, cfg: UrsoNetConfig, steps: int = 200,
                  batch: int = 16, seed: int = 0):
    tb, th, sb, sh = POLICIES[name]
    params, hist = train_ursonet(cfg, tb, th, steps=steps, batch=batch,
                                 seed=seed)
    loce, orie = eval_ursonet(params, cfg, sb, sh, batch=batch)
    return {"condition": name, "loce": loce, "orie": orie,
            "final_train_loss": hist[-1][1]}
