"""MPAI scheduler: partition-point and accelerator-assignment search.

The paper demonstrates one hand-chosen partition (conv backbone on the
DPU, FC head on the VPU) and names the general methodology future work.
This module implements that methodology: enumerate candidate partitions of
a layer-cost table across an accelerator pool, price each with the
roofline cost model, attach an accuracy penalty (measured, or the
precision prior), and return the speed-accuracy-energy Pareto frontier.

Invariants (property-tested):
  * every returned plan covers all layers contiguously;
  * no returned plan is Pareto-dominated by another returned plan;
  * the paper's configuration (INT8 backbone + FP16 head) lies on the
    frontier for UrsoNet-like workloads.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.accelerators import (PRECISION_ERROR_PRIOR, AcceleratorProfile,
                                     get_profile)
from repro.core.cost_model import LayerCost, SegmentCost, segment_cost
from repro.core.partition import PartitionPlan, Segment
from repro.core.precision import Precision, PrecisionPolicy


@dataclass(frozen=True)
class ScheduledPlan:
    assignments: Tuple[Tuple[int, int, str], ...]   # (start, end, profile)
    latency_s: float
    energy_j: float
    accuracy_penalty: float
    segment_costs: Tuple[SegmentCost, ...] = field(default=(), compare=False)

    def dominates(self, other: "ScheduledPlan") -> bool:
        le = (self.latency_s <= other.latency_s
              and self.energy_j <= other.energy_j
              and self.accuracy_penalty <= other.accuracy_penalty)
        lt = (self.latency_s < other.latency_s
              or self.energy_j < other.energy_j
              or self.accuracy_penalty < other.accuracy_penalty)
        return le and lt

    def to_partition_plan(self, qat: bool = False) -> PartitionPlan:
        segs = []
        for i, (s, e, prof) in enumerate(self.assignments):
            prec = get_profile(prof).precision
            if prec is Precision.INT8:
                pol = (PrecisionPolicy.int8_qat() if qat
                       else PrecisionPolicy.int8())
            elif prec is Precision.FP32:
                pol = PrecisionPolicy.fp32()
            else:
                pol = PrecisionPolicy.bf16()
            segs.append(Segment(f"seg{i}_{prof}", s, e, pol, prof))
        return PartitionPlan(tuple(segs))


def _plan_cost(layers: Sequence[LayerCost], cuts: Sequence[int],
               profiles: Sequence[str], batch: int,
               accuracy_penalty: Dict[str, float]) -> ScheduledPlan:
    bounds = [0, *cuts, len(layers)]
    seg_costs, assignments = [], []
    lat = energy = acc = 0.0
    for i, prof_name in enumerate(profiles):
        prof = get_profile(prof_name)
        lo, hi = bounds[i], bounds[i + 1]
        entry = layers[lo].act_in_elems if i > 0 else 0.0   # handoff at cut
        c = segment_cost(layers[lo:hi], prof, batch, entry_act_elems=entry)
        seg_costs.append(c)
        assignments.append((lo, hi, prof_name))
        lat += c.latency_s
        energy += c.energy_j
        share = sum(l.macs for l in layers[lo:hi]) / max(
            sum(l.macs for l in layers), 1.0)
        acc += accuracy_penalty.get(
            prof_name, PRECISION_ERROR_PRIOR[prof.precision]) * share
    return ScheduledPlan(tuple(assignments), lat, energy, acc, tuple(seg_costs))


def pareto_frontier(plans: Sequence[ScheduledPlan]) -> List[ScheduledPlan]:
    out = []
    for p in plans:
        if not any(q.dominates(p) for q in plans if q is not p):
            out.append(p)
    # dedupe identical objective triples
    seen, uniq = set(), []
    for p in sorted(out, key=lambda p: (p.latency_s, p.energy_j,
                                        p.accuracy_penalty)):
        key = (round(p.latency_s, 12), round(p.energy_j, 12),
               round(p.accuracy_penalty, 12))
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def schedule(layers: Sequence[LayerCost],
             profile_names: Sequence[str],
             batch: int = 1,
             max_segments: int = 2,
             accuracy_penalty: Optional[Dict[str, float]] = None,
             cut_candidates: Optional[Sequence[int]] = None
             ) -> List[ScheduledPlan]:
    """Enumerate 1- and 2-segment plans (the paper's design space) and
    return the Pareto frontier.  ``accuracy_penalty`` maps profile name ->
    measured penalty (overrides the precision prior — e.g. a QAT-trained
    int8 backbone measures near zero)."""
    accuracy_penalty = accuracy_penalty or {}
    n = len(layers)
    cuts = list(cut_candidates) if cut_candidates else list(range(1, n))
    plans: List[ScheduledPlan] = []
    for p0 in profile_names:
        plans.append(_plan_cost(layers, [], [p0], batch, accuracy_penalty))
    if max_segments >= 2:
        for cut in cuts:
            for p0 in profile_names:
                for p1 in profile_names:
                    if p0 == p1:
                        continue
                    plans.append(_plan_cost(layers, [cut], [p0, p1], batch,
                                            accuracy_penalty))
    return pareto_frontier(plans)


def best_under_accuracy(plans: Sequence[ScheduledPlan],
                        max_penalty: float) -> Optional[ScheduledPlan]:
    ok = [p for p in plans if p.accuracy_penalty <= max_penalty]
    return min(ok, key=lambda p: p.latency_s) if ok else None


def mpai_reference_plan(layers: Sequence[LayerCost], batch: int = 1,
                        head_layers: int = 1) -> ScheduledPlan:
    """The paper's deployed configuration: everything but the head on the
    INT8 DPU, the head on the FP16 VPU."""
    cut = len(layers) - head_layers
    return _plan_cost(layers, [cut], ["mpsoc_dpu", "myriadx_vpu"], batch,
                      {"mpsoc_dpu": 0.05})   # QAT'd backbone: measured ~small
