"""MPAI scheduler: partition-point and accelerator-assignment search.

The paper demonstrates one hand-chosen partition (conv backbone on the
DPU, FC head on the VPU) and names the general methodology future work.
This module implements that methodology: enumerate candidate partitions of
a layer-cost table across an accelerator pool, price each with the
roofline cost model, attach an accuracy penalty (measured, or the
precision prior), and return the speed-accuracy-energy Pareto frontier.

Invariants (property-tested):
  * every returned plan covers all layers contiguously;
  * no returned plan is Pareto-dominated by another returned plan;
  * the paper's configuration (INT8 backbone + FP16 head) lies on the
    frontier for UrsoNet-like workloads.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.accelerators import (PRECISION_ERROR_PRIOR, AcceleratorProfile,
                                     get_profile)
from repro.core.cost_model import LayerCost, SegmentCost, segment_cost
from repro.core.partition import PartitionPlan, Segment
from repro.core.precision import Precision, PrecisionPolicy


@dataclass(frozen=True)
class ScheduledPlan:
    assignments: Tuple[Tuple[int, int, str], ...]   # (start, end, profile)
    latency_s: float
    energy_j: float
    accuracy_penalty: float
    segment_costs: Tuple[SegmentCost, ...] = field(default=(), compare=False)

    def dominates(self, other: "ScheduledPlan") -> bool:
        le = (self.latency_s <= other.latency_s
              and self.energy_j <= other.energy_j
              and self.accuracy_penalty <= other.accuracy_penalty)
        lt = (self.latency_s < other.latency_s
              or self.energy_j < other.energy_j
              or self.accuracy_penalty < other.accuracy_penalty)
        return le and lt

    def to_partition_plan(self, qat: bool = False) -> PartitionPlan:
        segs = []
        for i, (s, e, prof) in enumerate(self.assignments):
            prec = get_profile(prof).precision
            if prec is Precision.INT8:
                pol = (PrecisionPolicy.int8_qat() if qat
                       else PrecisionPolicy.int8())
            elif prec is Precision.FP32:
                pol = PrecisionPolicy.fp32()
            else:
                pol = PrecisionPolicy.bf16()
            segs.append(Segment(f"seg{i}_{prof}", s, e, pol, prof))
        return PartitionPlan(tuple(segs))


def _plan_cost(layers: Sequence[LayerCost], cuts: Sequence[int],
               profiles: Sequence[str], batch: int,
               accuracy_penalty: Dict[str, float]) -> ScheduledPlan:
    bounds = [0, *cuts, len(layers)]
    seg_costs, assignments = [], []
    lat = energy = acc = 0.0
    for i, prof_name in enumerate(profiles):
        prof = get_profile(prof_name)
        lo, hi = bounds[i], bounds[i + 1]
        entry = layers[lo].act_in_elems if i > 0 else 0.0   # handoff at cut
        c = segment_cost(layers[lo:hi], prof, batch, entry_act_elems=entry)
        seg_costs.append(c)
        assignments.append((lo, hi, prof_name))
        lat += c.latency_s
        energy += c.energy_j
        share = sum(l.macs for l in layers[lo:hi]) / max(
            sum(l.macs for l in layers), 1.0)
        acc += accuracy_penalty.get(
            prof_name, PRECISION_ERROR_PRIOR[prof.precision]) * share
    return ScheduledPlan(tuple(assignments), lat, energy, acc, tuple(seg_costs))


def pareto_frontier(plans: Sequence[ScheduledPlan]) -> List[ScheduledPlan]:
    """Sort-based skyline sweep, O(n log n) amortized (was O(n²) pairwise).

    Plans are visited in (latency, energy, accuracy) order, so any
    potential dominator of plan p precedes p.  A staircase over
    (energy, accuracy) — energies strictly increasing, accuracies strictly
    decreasing — summarizes all kept plans: p is dominated iff some kept
    plan has energy <= p.energy and accuracy <= p.accuracy (its latency is
    <= p's by visit order, and exact objective ties are deduped first, so
    at least one inequality is strict).
    """
    order = sorted(plans, key=lambda p: (p.latency_s, p.energy_j,
                                         p.accuracy_penalty))
    seen, cand = set(), []
    for p in order:
        key = (round(p.latency_s, 12), round(p.energy_j, 12),
               round(p.accuracy_penalty, 12))
        if key not in seen:
            seen.add(key)
            cand.append(p)
    es: List[float] = []      # staircase energies, strictly increasing
    accs: List[float] = []    # staircase accuracies, strictly decreasing
    out: List[ScheduledPlan] = []
    for p in cand:
        e, a = p.energy_j, p.accuracy_penalty
        i = bisect.bisect_right(es, e)
        if i and accs[i - 1] <= a:
            continue                          # dominated by an earlier plan
        out.append(p)
        if i and es[i - 1] == e:              # tighter accuracy at same energy
            i -= 1
            del es[i], accs[i]
        j = i                                 # drop steps p now supersedes
        while j < len(es) and accs[j] >= a:
            j += 1
        del es[i:j], accs[i:j]
        es.insert(i, e)
        accs.insert(i, a)
    return out


def schedule(layers: Sequence[LayerCost],
             profile_names: Sequence[str],
             batch: int = 1,
             max_segments: int = 2,
             accuracy_penalty: Optional[Dict[str, float]] = None,
             cut_candidates: Optional[Sequence[int]] = None
             ) -> List[ScheduledPlan]:
    """Enumerate 1- and 2-segment plans (the paper's design space) and
    return the Pareto frontier.  ``accuracy_penalty`` maps profile name ->
    measured penalty (overrides the precision prior — e.g. a QAT-trained
    int8 backbone measures near zero)."""
    accuracy_penalty = accuracy_penalty or {}
    n = len(layers)
    cuts = list(cut_candidates) if cut_candidates else list(range(1, n))
    plans: List[ScheduledPlan] = []
    for p0 in profile_names:
        plans.append(_plan_cost(layers, [], [p0], batch, accuracy_penalty))
    if max_segments >= 2:
        for cut in cuts:
            for p0 in profile_names:
                for p1 in profile_names:
                    if p0 == p1:
                        continue
                    plans.append(_plan_cost(layers, [cut], [p0, p1], batch,
                                            accuracy_penalty))
    return pareto_frontier(plans)


def best_under_accuracy(plans: Sequence[ScheduledPlan],
                        max_penalty: float) -> Optional[ScheduledPlan]:
    ok = [p for p in plans if p.accuracy_penalty <= max_penalty]
    return min(ok, key=lambda p: p.latency_s) if ok else None


def plan_profiles(plan: ScheduledPlan) -> frozenset:
    """Accelerator profiles a plan needs — a pool can host the plan iff it
    still has every one of them."""
    return frozenset(prof for _, _, prof in plan.assignments)


def price_assignments(layers: Sequence[LayerCost],
                      plan: ScheduledPlan, batch: int
                      ) -> Tuple[float, float]:
    """Re-price a plan's segment assignments at an actual batch size.

    ``schedule()`` prices the design space at a nominal batch; the router
    batches dynamically, so dispatch re-prices each flush with the real
    occupancy.  Returns ``(latency_s, energy_j)``.
    """
    lat = energy = 0.0
    for lo, hi, prof_name in plan.assignments:
        prof = get_profile(prof_name)
        entry = layers[lo].act_in_elems if lo > 0 else 0.0
        c = segment_cost(layers[lo:hi], prof, batch, entry_act_elems=entry)
        lat += c.latency_s
        energy += c.energy_j
    return lat, energy


def reschedule_over_subset(layers: Sequence[LayerCost],
                           profile_names: Sequence[str],
                           lost: Iterable[str] = (),
                           batch: int = 1,
                           max_segments: int = 2,
                           accuracy_penalty: Optional[Dict[str, float]] = None,
                           cut_candidates: Optional[Sequence[int]] = None
                           ) -> List[ScheduledPlan]:
    """Failover path: re-run the search over the surviving profile subset.

    Simply *filtering* an existing frontier is not enough — dropping a
    lost profile's plans can resurrect survivor-only plans they used to
    dominate — so the frontier must be recomputed from scratch (cheap now
    that ``pareto_frontier`` is a skyline sweep).  Returns ``[]`` when no
    profile survives.
    """
    lost_set = set(lost)
    surviving = [p for p in profile_names if p not in lost_set]
    if not surviving:
        return []
    return schedule(layers, surviving, batch=batch,
                    max_segments=max_segments,
                    accuracy_penalty=accuracy_penalty,
                    cut_candidates=cut_candidates)


def mpai_reference_plan(layers: Sequence[LayerCost], batch: int = 1,
                        head_layers: int = 1) -> ScheduledPlan:
    """The paper's deployed configuration: everything but the head on the
    INT8 DPU, the head on the FP16 VPU."""
    cut = len(layers) - head_layers
    return _plan_cost(layers, [cut], ["mpsoc_dpu", "myriadx_vpu"], batch,
                      {"mpsoc_dpu": 0.05})   # QAT'd backbone: measured ~small
