"""Co-processing stage pipeline — the DPU->VPU handoff at pod scale.

The paper streams activations from the INT8 engine to the FP16 engine over
a board-level link.  At pod scale the analogue is a *stage axis* of the
device mesh: device group s holds segment s's parameters and executes its
precision policy; activations hand off to group s+1 with
``lax.ppermute`` while group s starts the next microbatch — a
double-buffered inference pipeline (GPipe-style schedule, depth-1 buffers).

Implemented with ``shard_map`` over the stage axis.  All stages execute the
same program; ``lax.switch`` on the stage index selects the segment body,
so only the resident segment actually runs per device group.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # jax >= 0.4.35
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):       # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def pipeline_apply(mesh: Mesh, stage_axis: str,
                   stage_fns: Sequence[Callable],
                   stage_params_stacked,
                   micro_inputs: jnp.ndarray,
                   hidden_shape: tuple, out_shape: tuple,
                   hidden_dtype=jnp.bfloat16, out_dtype=jnp.float32):
    """Run ``micro_inputs`` [n_micro, ...] through a linear stage pipeline.

    ``stage_fns[s](x, params_s) -> (hidden, out)``: stage s consumes the
    previous stage's hidden (stage 0 consumes the raw microbatch) and
    emits (hidden_for_next, final_output_or_zeros).

    ``stage_params_stacked``: pytree with leading dim = num_stages,
    sharded over ``stage_axis``.
    Returns outputs [n_micro, *out_shape] (valid output of the last stage).
    """
    num_stages = len(stage_fns)
    n_micro = micro_inputs.shape[0]
    steps = n_micro + num_stages - 1
    perm = [(s, s + 1) for s in range(num_stages - 1)]

    def body(params_local, xs_local):
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)

        def run(x):
            branches = [partial(fn, params=params_local) for fn in stage_fns]
            return jax.lax.switch(stage, branches, x)

        def step(carry, t):
            buf, outs = carry
            feed_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs_local, feed_idx, 0,
                                                keepdims=False)
            x = jnp.where(stage == 0,
                          feed.astype(hidden_dtype),
                          buf)
            hidden, out = run(x)
            buf_next = jax.lax.ppermute(hidden, stage_axis, perm)
            out_idx = jnp.clip(t - (num_stages - 1), 0, n_micro - 1)
            take = t >= (num_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, out.astype(out_dtype),
                                jax.lax.dynamic_index_in_dim(
                                    outs, out_idx, 0, keepdims=False)),
                out_idx, 0)
            return (buf_next, outs), None

        buf0 = jnp.zeros(hidden_shape, hidden_dtype)
        outs0 = jnp.zeros((n_micro,) + out_shape, out_dtype)
        (_, outs), _ = jax.lax.scan(step, (buf0, outs0),
                                    jnp.arange(steps))
        return outs[None]              # leading stage dim for out_specs

    fn = shard_map(body, mesh,
                   in_specs=(P(stage_axis), P()),
                   out_specs=P(stage_axis))
    outs_per_stage = fn(stage_params_stacked, micro_inputs)
    return outs_per_stage[-1]          # the last stage's buffer is the answer


# ---------------------------------------------------------------------------
# LM convenience: two-stage MPAI serve pipeline
# ---------------------------------------------------------------------------
def lm_two_stage_fns(cfg, plan, tp: int = 1):
    """Build (stage0_fn, stage1_fn) for an LM split at plan.segments[0].end.

    Stage 0: embed + backbone segment (int8 policy).
    Stage 1: tail segment + final norm + head (high precision).
    Stage params: {'embed':..., 'layers': <segment slice>, ...}.
    """
    from repro.models import transformer as T
    from repro.models.layers import lm_logits, make_norm

    period = T.pattern_period(cfg)
    plan = plan.align_to_period(period, cfg.num_layers)
    seg0, seg1 = plan.segments[0], plan.segments[-1]

    def stage0(tokens_embed, params):
        # tokens arrive pre-embedded (embedding runs host-side or in-stage;
        # here in-stage via the passed embed table)
        x = tokens_embed
        x, _, _ = T._segment_scan(params["layers"], cfg, x,
                                  _positions(x), seg0.policy, tp)
        return x, jnp.zeros(x.shape[:-1] + (cfg.vocab_size,), jnp.float32)

    def stage1(x, params):
        x, _, _ = T._segment_scan(params["layers"], cfg, x,
                                  _positions(x), seg1.policy, tp)
        _, norm = make_norm("rmsnorm")
        x = norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_logits(params["lm_head"], x, plan.head_policy)
        return jnp.zeros_like(x), logits.astype(jnp.float32)

    def _positions(x):
        return jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                x.shape[:2])
    return stage0, stage1, (seg0, seg1)


def split_lm_params_for_stages(params, cfg, plan, period: int):
    """Split a monolithic LM param tree into per-stage trees with identical
    structure (required for stacking over the stage axis).  Stage trees are
    padded with zero-size-compatible entries where a stage lacks a part."""
    import jax.numpy as jnp
    from repro.models.transformer import _slice_stack

    seg0, seg1 = plan.segments[0], plan.segments[-1]
    lo = seg0.end // period
    table = params["embed"] if "lm_head" not in params else params["lm_head"]
    n0, n1 = lo, (seg1.end - seg1.start) // period
    assert n0 == n1, ("two-stage pipeline requires equal segment lengths; "
                      f"got {n0} vs {n1} super-blocks")
    s0 = {"layers": _slice_stack(params["layers"], 0, lo),
          "final_norm": jax.tree_util.tree_map(jnp.zeros_like,
                                               params["final_norm"]),
          "lm_head": jnp.zeros_like(table)}
    s1 = {"layers": _slice_stack(params["layers"], lo, lo + n1),
          "final_norm": params["final_norm"],
          "lm_head": table}
    return jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), s0, s1)
