"""Network partitioning — the MPAI contribution's structural half.

A :class:`PartitionPlan` splits a layered network into contiguous
:class:`Segment`s, each pinned to a precision policy and (logically) an
accelerator profile.  The paper's deployed configuration is the two-way
split — compute-heavy backbone on the INT8 engine, accuracy-critical head
on the FP16 engine — which :meth:`PartitionPlan.mpai` reproduces for any
layer count.  Plans are frozen/hashable so they can be jit static args,
and segment boundaries become scan boundaries in the model stack (each
segment scans its layers under its own policy).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

from repro.core.precision import Precision, PrecisionPolicy


@dataclass(frozen=True)
class Segment:
    name: str
    start: int                       # first layer (inclusive)
    end: int                         # last layer (exclusive)
    policy: PrecisionPolicy
    accelerator: str = "tpu_v5e_bf16"  # cost-model profile / stage assignment

    @property
    def num_layers(self) -> int:
        return self.end - self.start


@dataclass(frozen=True)
class PartitionPlan:
    segments: Tuple[Segment, ...]
    embed_policy: PrecisionPolicy = field(default_factory=PrecisionPolicy.bf16)
    head_policy: PrecisionPolicy = field(default_factory=PrecisionPolicy.bf16)

    def validate(self, num_layers: int, period: int = 1) -> None:
        segs = self.segments
        if not segs:
            raise ValueError("empty plan")
        if segs[0].start != 0 or segs[-1].end != num_layers:
            raise ValueError(f"plan does not cover [0, {num_layers})")
        for a, b in zip(segs, segs[1:]):
            if a.end != b.start:
                raise ValueError(f"segments {a.name}/{b.name} not contiguous")
        for s in segs:
            if s.num_layers <= 0:
                raise ValueError(f"segment {s.name} is empty")
            if s.start % period or s.end % period:
                raise ValueError(
                    f"segment {s.name} [{s.start},{s.end}) not aligned to the "
                    f"layer-pattern period {period}")

    # ------------------------------------------------------------------
    # canonical plans
    # ------------------------------------------------------------------
    @classmethod
    def uniform(cls, num_layers: int, policy: PrecisionPolicy | None = None,
                accelerator: str = "tpu_v5e_bf16") -> "PartitionPlan":
        policy = policy or PrecisionPolicy.bf16()
        return cls((Segment("all", 0, num_layers, policy, accelerator),),
                   embed_policy=policy if not policy.precision.is_quantized
                   else PrecisionPolicy.bf16(),
                   head_policy=policy if not policy.precision.is_quantized
                   else PrecisionPolicy.bf16())

    @classmethod
    def mpai(cls, num_layers: int, split: int | None = None,
             mode: str = "fake", use_pallas: bool = False) -> "PartitionPlan":
        """The paper's deployment: int8 backbone + high-precision head.

        ``split``: layer index where the high-precision tail begins
        (default: last layer only — the transformer analogue of UrsoNet's
        FC heads).  ``mode``: 'fake' (QAT training) or 'quant' (serving).
        """
        split = num_layers - 1 if split is None else split
        split = max(1, min(split, num_layers))
        int8 = (PrecisionPolicy.int8_qat() if mode == "fake"
                else PrecisionPolicy.int8(use_pallas=use_pallas))
        segs = [Segment("backbone", 0, split, int8, "tpu_v5e_int8")]
        if split < num_layers:
            segs.append(Segment("head", split, num_layers,
                                PrecisionPolicy.bf16(), "tpu_v5e_bf16"))
        return cls(tuple(segs))

    @classmethod
    def int8_all(cls, num_layers: int, mode: str = "quant",
                 use_pallas: bool = False) -> "PartitionPlan":
        """Everything quantized (the paper's DPU-only row — fast, lossy)."""
        pol = (PrecisionPolicy.int8_qat() if mode == "fake"
               else PrecisionPolicy.int8(use_pallas=use_pallas))
        return cls((Segment("all", 0, num_layers, pol, "tpu_v5e_int8"),))

    def align_to_period(self, period: int, num_layers: int) -> "PartitionPlan":
        """Snap segment boundaries to multiples of the layer-pattern period."""
        if period <= 1:
            return self
        snap = lambda x: max(period, min((x // period) * period, num_layers))
        segs, prev = [], 0
        for s in self.segments[:-1]:
            e = snap(s.end)
            if e > prev:
                segs.append(Segment(s.name, prev, e, s.policy, s.accelerator))
                prev = e
        last = self.segments[-1]
        if prev < num_layers:
            segs.append(Segment(last.name, prev, num_layers, last.policy,
                                last.accelerator))
        return PartitionPlan(tuple(segs), self.embed_policy, self.head_policy)


def default_plan(num_layers: int) -> PartitionPlan:
    return PartitionPlan.uniform(num_layers)
