"""Precision lattice and execution policies (the MPAI precision axis).

The paper's accelerators define three operating precisions — INT8 (DPU,
Edge TPU), FP16 (MyriadX VPU) and FP32 (Cortex CPUs).  On TPU the native
fast float is bf16, so FP16 policies map to bf16 (DESIGN.md §9); INT8 maps
to the MXU int8 path implemented by ``kernels/int8_matmul.py``.

A :class:`PrecisionPolicy` tells a model segment *how to execute its
matmuls*; it is threaded through the model code via ``pdot`` (see
``core/quantization.py``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import jax.numpy as jnp


class Precision(enum.Enum):
    INT8 = "int8"
    FP16 = "fp16"       # executed as bf16 on TPU
    BF16 = "bf16"
    FP32 = "fp32"

    @property
    def compute_dtype(self):
        if self is Precision.FP32:
            return jnp.float32
        if self in (Precision.FP16, Precision.BF16):
            return jnp.bfloat16
        return jnp.bfloat16  # int8 path dequantizes into bf16

    @property
    def bytes_per_weight(self) -> float:
        return 1.0 if self is Precision.INT8 else (4.0 if self is Precision.FP32 else 2.0)

    @property
    def is_quantized(self) -> bool:
        return self is Precision.INT8


@dataclass(frozen=True)
class PrecisionPolicy:
    """How a segment executes.

    mode:
      * ``raw``   — plain matmul in ``precision.compute_dtype``
      * ``fake``  — fake-quant (QAT training of int8 segments, STE gradients)
      * ``quant`` — real int8 execution (weights pre-quantized, dynamic
                    per-tensor activation quantization)
    """
    precision: Precision = Precision.BF16
    mode: str = "raw"
    per_channel: bool = True          # weight scales per output channel
    use_pallas: bool = False          # route int8 matmuls through the Pallas kernel

    def __post_init__(self):
        if self.mode in ("fake", "quant") and self.precision is not Precision.INT8:
            raise ValueError("fake/quant modes are int8-only")

    @classmethod
    def bf16(cls) -> "PrecisionPolicy":
        return cls(Precision.BF16, "raw")

    @classmethod
    def fp32(cls) -> "PrecisionPolicy":
        return cls(Precision.FP32, "raw")

    @classmethod
    def int8_qat(cls) -> "PrecisionPolicy":
        return cls(Precision.INT8, "fake")

    @classmethod
    def int8(cls, use_pallas: bool = False) -> "PrecisionPolicy":
        return cls(Precision.INT8, "quant", use_pallas=use_pallas)


DEFAULT_POLICY = PrecisionPolicy.bf16()
