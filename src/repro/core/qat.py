"""Partition-aware training (the paper's §III key enabler).

The model is trained *knowing the deployment partition*: segments that
will execute on an INT8 engine train with fake-quantization (STE), so the
quantized pipeline recovers baseline accuracy — Table I's DPU+VPU row.

This module converts between the three lifecycle phases of a plan:

  train  (int8 segments -> fake-quant)   ->  serve (int8 -> real quant)
                                          ->  eval  (any -> raw bf16 baseline)
"""
from __future__ import annotations

import dataclasses

from repro.core.partition import PartitionPlan, Segment
from repro.core.precision import Precision, PrecisionPolicy


def _convert(plan: PartitionPlan, mode: str,
             use_pallas: bool = False) -> PartitionPlan:
    segs = []
    for s in plan.segments:
        if s.policy.precision is Precision.INT8:
            pol = dataclasses.replace(s.policy, mode=mode,
                                      use_pallas=use_pallas and mode == "quant")
            segs.append(Segment(s.name, s.start, s.end, pol, s.accelerator))
        else:
            segs.append(s)
    return PartitionPlan(tuple(segs), plan.embed_policy, plan.head_policy)


def train_plan(plan: PartitionPlan) -> PartitionPlan:
    """int8 segments -> fake-quant (QAT)."""
    return _convert(plan, "fake")


def serve_plan(plan: PartitionPlan, use_pallas: bool = False) -> PartitionPlan:
    """int8 segments -> real int8 execution."""
    return _convert(plan, "quant", use_pallas)


def baseline_plan(plan: PartitionPlan) -> PartitionPlan:
    """Strip quantization everywhere (the fp32/bf16 reference rows)."""
    segs = tuple(Segment(s.name, s.start, s.end, PrecisionPolicy.bf16(),
                         "tpu_v5e_bf16") for s in plan.segments)
    return PartitionPlan(segs, PrecisionPolicy.bf16(), PrecisionPolicy.bf16())
