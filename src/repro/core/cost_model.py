"""Three-term roofline cost model over accelerator profiles.

latency(segment, profile) = max(compute, memory) + handoff
  compute = 2 * MACs / sustained_ops
  memory  = (weight_bytes + activation_bytes) / mem_bw
  handoff = boundary activation bytes / link_bw      (charged at segment entry)

energy = power * latency  (+ link energy folded into power)

The same machinery prices (a) the paper's CNN layer tables on the paper's
devices (Table I / Fig. 2 reproduction) and (b) transformer segments on
TPU profiles (scheduler input).  Precision changes both the ops rate
(profile) and the bytes moved (weights shrink to 1 B for INT8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.core.accelerators import AcceleratorProfile
from repro.core.precision import Precision


@dataclass(frozen=True)
class SegmentCost:
    name: str
    compute_s: float
    memory_s: float
    handoff_s: float
    energy_j: float

    @property
    def latency_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.handoff_s

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "handoff": self.handoff_s}
        return max(terms, key=terms.get)


@dataclass(frozen=True)
class LayerCost:
    """Workload description of one layer (precision-independent)."""
    name: str
    macs: float
    weight_elems: float
    act_in_elems: float
    act_out_elems: float
    kind: str = "dense"       # dense | depthwise (poor MAC utilization)


def layer_costs_from_convspecs(specs) -> List[LayerCost]:
    out, prev_act = [], 0.0
    for s in specs:
        out.append(LayerCost(s.name, s.macs, s.params,
                             prev_act or s.activations, s.activations,
                             kind=getattr(s, "kind", "dense")))
        prev_act = s.activations
    return out


def segment_cost(layers: Sequence[LayerCost], profile: AcceleratorProfile,
                 batch: int = 1, entry_act_elems: float = 0.0,
                 act_bytes: float | None = None) -> SegmentCost:
    """Price a contiguous run of layers on one accelerator."""
    wbytes = profile.precision.bytes_per_weight
    abytes = act_bytes if act_bytes is not None else (
        1.0 if profile.precision is Precision.INT8 else 2.0)
    dw_eff = getattr(profile, "depthwise_eff", 1.0)
    macs_eff = sum(l.macs / (dw_eff if l.kind == "depthwise" else 1.0)
                   for l in layers) * batch
    weight_b = sum(l.weight_elems for l in layers) * wbytes
    act_b = sum(l.act_in_elems + l.act_out_elems for l in layers) * batch * abytes
    compute = 2.0 * macs_eff / profile.sustained_ops
    wbw = getattr(profile, "weight_bw", 0.0) or profile.mem_bw
    memory = weight_b / wbw + act_b / profile.mem_bw
    handoff = (entry_act_elems * batch * abytes) / profile.link_bw \
        + profile.overhead_s
    lat = max(compute, memory) + handoff
    return SegmentCost("+".join(l.name for l in layers[:1]) +
                       (f"..x{len(layers)}" if len(layers) > 1 else ""),
                       compute, memory, handoff, profile.power_w * lat)


def network_latency(layers: Sequence[LayerCost], profile: AcceleratorProfile,
                    batch: int = 1) -> SegmentCost:
    return segment_cost(layers, profile, batch)


def fps(layers: Sequence[LayerCost], profile: AcceleratorProfile) -> float:
    return 1.0 / network_latency(layers, profile).latency_s


# ---------------------------------------------------------------------------
# Transformer layer tables (scheduler input for the assigned archs)
# ---------------------------------------------------------------------------
def transformer_layer_costs(cfg, seq_len: int) -> List[LayerCost]:
    """Per-layer MACs/weights/activations for one sample of length S."""
    d, f, s = cfg.d_model, cfg.d_ff, seq_len
    hd = cfg.resolved_head_dim()
    out: List[LayerCost] = []
    for i in range(cfg.num_layers):
        kind = cfg.layer_mixer(i)
        macs = w = 0.0
        if kind == "attention":
            qo = d * cfg.num_heads * hd
            kv = d * cfg.num_kv_heads * hd
            w += 2 * qo + 2 * kv
            macs += s * (2 * qo + 2 * kv)
            eff_s = min(s, cfg.sliding_window) if cfg.sliding_window else s
            macs += 2 * s * eff_s * cfg.num_heads * hd / 2  # causal halves it
        elif kind == "mamba":
            mc = cfg.mamba
            di = mc.expand * d
            w += 3 * d * di + di * (mc.resolved_dt_rank(d) + 2 * mc.d_state)
            macs += s * (3 * d * di + di * mc.d_state * 2)
        elif kind == "rwkv6":
            w += 6 * d * d
            macs += s * (6 * d * d + d * 64)
        if cfg.is_moe_layer(i):
            e = cfg.moe
            per = d * e.d_ff_expert * (3 if cfg.glu else 2)
            w += e.num_experts * per
            macs += s * e.top_k * per
        elif kind != "rwkv6":
            w += d * f * (3 if cfg.glu else 2)
            macs += s * d * f * (3 if cfg.glu else 2)
        else:
            w += 3 * d * f
            macs += s * 3 * d * f
        out.append(LayerCost(f"layer_{i}", macs, w, s * d, s * d))
    return out
