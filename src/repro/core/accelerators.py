"""Accelerator profiles — the heterogeneity MPAI schedules against.

The paper's four devices are reproduced from their datasheets / the
paper's own numbers so that Table I and Fig. 2 can be re-derived from the
roofline cost model; the TPU v5e profiles are the deployment targets of
this framework (per-precision operating points of the same chip — MPAI's
"different accelerators" generalizes to "different operating points of a
homogeneous pod", DESIGN.md §2).

Peak numbers:
  * DPUCZDX8G on ZCU104 (2x B4096 @ ~300 MHz): ~2.4 TOPS INT8, DDR4 ~19 GB/s
  * MyriadX NCS2: ~1 TOPS effective FP16 (4 TOPS marketing peak derated to
    the sustained DNN rate the paper observes), LPDDR4 ~8.5 GB/s (on-chip
    2.5 MB CMX buffers most reuse)
  * Edge TPU: 4 TOPS INT8, ~8 MB on-chip SRAM, LPDDR4 ~4 GB/s off-chip
  * Cortex-A53 quad @1.2-1.5 GHz: ~10-20 GFLOP/s NEON
  * TPU v5e: 197 TFLOP/s bf16 / 394 TOPS int8, 819 GB/s HBM, ~50 GB/s/link
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.precision import Precision


@dataclass(frozen=True)
class AcceleratorProfile:
    name: str
    precision: Precision
    peak_ops: float           # ops/s at `precision` (MAC = 2 ops)
    mem_bw: float             # bytes/s to its activation/weight store
    link_bw: float            # bytes/s to the host / peer (handoff cost)
    power_w: float            # board-level active power
    efficiency: float = 0.6   # sustained fraction of peak on DNN layers
    overhead_s: float = 0.0   # per-inference dispatch/transfer latency
    depthwise_eff: float = 1.0  # MAC utilization on depthwise/grouped convs
    weight_bw: float = 0.0    # weight-fetch bandwidth (0 -> mem_bw); models
    #   the Edge TPU's off-chip weight spill when a model exceeds its SRAM

    @property
    def sustained_ops(self) -> float:
        return self.peak_ops * self.efficiency


GB = 1e9
T = 1e12

# Efficiencies are CALIBRATED against the paper's own Table I effective
# rates (UrsoNet latency -> sustained ops/s): Cortex-A53 ~3 GFLOP/s fp32,
# VPU ~120 GFLOP/s fp16 (246 ms), Edge TPU ~200 GOP/s with DDR spill
# (149 ms), DPU ~560 GOP/s (53 ms).  overhead_s models per-inference
# dispatch + host transfer (dominates small nets on USB devices — the
# Fig. 2 MobileNet crossover).
PROFILES: Dict[str, AcceleratorProfile] = {
    # --- the paper's devices (for Table I / Fig. 2 reproduction) -----------
    "cortex_a53": AcceleratorProfile(
        "cortex_a53", Precision.FP32, 12e9, 4 * GB, 1 * GB, 4.0, 0.25),
    "cortex_a53_fp16": AcceleratorProfile(
        "cortex_a53_fp16", Precision.FP16, 24e9, 4 * GB, 1 * GB, 4.0, 0.29),
    "myriadx_vpu": AcceleratorProfile(
        "myriadx_vpu", Precision.FP16, 1.0 * T, 8.5 * GB, 0.4 * GB, 2.0,
        0.12, overhead_s=1.5e-3, depthwise_eff=0.1),
    "edge_tpu": AcceleratorProfile(
        "edge_tpu", Precision.INT8, 4.0 * T, 4.0 * GB, 0.3 * GB, 2.0,
        0.5, overhead_s=1.8e-3, depthwise_eff=0.5),
    "mpsoc_dpu": AcceleratorProfile(
        "mpsoc_dpu", Precision.INT8, 4.9 * T, 19.2 * GB, 12.8 * GB, 10.0,
        0.115, overhead_s=2e-4, depthwise_eff=0.25),
    # --- deployment target: TPU v5e per-precision operating points ---------
    "tpu_v5e_bf16": AcceleratorProfile(
        "tpu_v5e_bf16", Precision.BF16, 197e12, 819 * GB, 50 * GB, 170.0, 0.55),
    "tpu_v5e_int8": AcceleratorProfile(
        "tpu_v5e_int8", Precision.INT8, 394e12, 819 * GB, 50 * GB, 170.0, 0.55),
    "tpu_v5e_fp32": AcceleratorProfile(
        "tpu_v5e_fp32", Precision.FP32, 49e12, 819 * GB, 50 * GB, 170.0, 0.55),
}


def get_profile(name: str) -> AcceleratorProfile:
    return PROFILES[name]


# Accuracy priors for the scheduler (relative error-budget units): what the
# paper's Table I encodes empirically — INT8 PTQ hurts, FP16 is near-lossless,
# QAT recovers most of INT8's loss.  Used as a *prior* when no measured
# accuracy is supplied.
PRECISION_ERROR_PRIOR = {
    Precision.FP32: 0.0,
    Precision.BF16: 0.01,
    Precision.FP16: 0.01,
    Precision.INT8: 0.30,     # PTQ prior; QAT-trained segments pass measured
}
