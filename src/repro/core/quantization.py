"""Symmetric int8 quantization — the numerical core of MPAI's DPU/TPU path.

Implements:
  * per-channel / per-tensor symmetric absmax quantization (PTQ),
  * fake-quantization with straight-through-estimator gradients (QAT —
    the paper's "partition-aware model training"),
  * ``pdot`` — the precision-dispatched matmul every model layer calls.

``pdot`` is where the MPAI partition plan meets the compute graph: a
segment's :class:`~repro.core.precision.PrecisionPolicy` decides whether a
matmul runs raw (bf16/fp32), fake-quantized (training the int8 segment), or
truly quantized (int8 MXU kernel / XLA int8 dot on the serving path).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.precision import Precision, PrecisionPolicy, DEFAULT_POLICY

INT8_QMAX = 127.0


class QTensor(NamedTuple):
    """An int8 tensor with its dequantization scale.

    ``scale`` broadcasts against ``values``: per-tensor -> shape (1,)*ndim,
    per-channel -> 1 everywhere except the channel axis.
    """
    values: jnp.ndarray                # int8
    scale: jnp.ndarray                 # f32, values ≈ float / scale

    def dequantize(self, dtype=jnp.bfloat16) -> jnp.ndarray:
        return (self.values.astype(jnp.float32) * self.scale).astype(dtype)


def _absmax_scale(x: jnp.ndarray, axis, keepdims=True) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)
    return jnp.maximum(amax, 1e-8) / INT8_QMAX


def quantize(x: jnp.ndarray, channel_axis: Optional[int] = None,
             batch_axes: tuple = ()) -> QTensor:
    """Symmetric absmax quantization to int8.

    ``channel_axis``: axis that keeps its own scale (None -> per-tensor).
    ``batch_axes``: additional axes that keep independent scales (e.g. the
    stacked-layer dim of scan weights).
    """
    if channel_axis is None:
        axis = tuple(i for i in range(x.ndim) if i not in
                     tuple(a % x.ndim for a in batch_axes))
        axis = axis or tuple(range(x.ndim))
    else:
        channel_axis = channel_axis % x.ndim
        keep = {channel_axis, *(a % x.ndim for a in batch_axes)}
        axis = tuple(i for i in range(x.ndim) if i not in keep)
    scale = _absmax_scale(x, axis)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_QMAX, INT8_QMAX)
    return QTensor(q.astype(jnp.int8), scale)


def fake_quant(x: jnp.ndarray, channel_axis: Optional[int] = None) -> jnp.ndarray:
    """Quantize-dequantize with straight-through-estimator gradients.

    Forward: round-trip through the int8 grid.  Backward: identity — the
    STE that makes partition-aware (QAT) training possible.
    """
    qt = quantize(x, channel_axis)
    dq = qt.dequantize(jnp.float32).astype(x.dtype)
    return x + jax.lax.stop_gradient(dq - x)


# ---------------------------------------------------------------------------
# pdot — the precision-dispatched matmul
# ---------------------------------------------------------------------------
def _int8_dot(x: jnp.ndarray, w: QTensor, use_pallas: bool) -> jnp.ndarray:
    """x: [..., K] float;  w: QTensor [K, N] (per-out-channel scales)."""
    xq = quantize(x)                                        # per-tensor dynamic
    lead = xq.values.shape[:-1]
    xm = xq.values.reshape(-1, xq.values.shape[-1])
    if use_pallas:
        from repro.kernels import ops as kops
        acc = kops.int8_matmul(xm, w.values)                # int32 [M, N]
    else:
        acc = jax.lax.dot_general(
            xm, w.values, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * xq.scale * w.scale.reshape(1, -1)
    return out.reshape(*lead, -1).astype(jnp.bfloat16)


def pdot(x: jnp.ndarray, w, policy: PrecisionPolicy = DEFAULT_POLICY) -> jnp.ndarray:
    """Precision-dispatched ``x @ w``.

    ``w`` is either a float array [K, N] (raw / fake modes) or a
    :class:`QTensor` (quant mode, weights pre-quantized offline).
    """
    if policy.mode == "quant":
        if not isinstance(w, QTensor):
            w = quantize(w, channel_axis=-1 if policy.per_channel else None)
        return _int8_dot(x, w, policy.use_pallas)
    dt = policy.precision.compute_dtype
    if isinstance(w, QTensor):       # pre-quantized checkpoint, raw segment
        w = w.dequantize(dt)         # (head segments of a served MPAI model)
    if policy.mode == "fake":
        w = fake_quant(w, channel_axis=-1 if policy.per_channel else None)
        x = fake_quant(x)
    return jnp.matmul(x.astype(dt), w.astype(dt))


def quantize_params(params, channel_axis: int = -1, stacked: bool = False):
    """Offline weight quantization of a param pytree: every float matrix
    with ndim >= 2 becomes a QTensor (per-out-channel); vectors (norms,
    biases) stay float.  ``stacked``: leading dim is a scan-layer stack
    that keeps independent scales.  Used when deploying to the int8 path."""
    def _q(p):
        if isinstance(p, jnp.ndarray) and p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            batch = (0,) if (stacked and p.ndim >= 3) else ()
            return quantize(p, channel_axis=channel_axis, batch_axes=batch)
        return p
    return jax.tree_util.tree_map(_q, params)


def quantization_error(x: jnp.ndarray, channel_axis: Optional[int] = None) -> jnp.ndarray:
    """Max abs round-trip error — bounded by scale/2 (property-tested)."""
    qt = quantize(x, channel_axis)
    return jnp.max(jnp.abs(qt.dequantize(jnp.float32) - x.astype(jnp.float32)))
