"""Heterogeneous fleet router: SLO-aware dispatch with online failover.

MPAI's offline result is a speed/accuracy/energy Pareto frontier over a
pool of diverse accelerators (INT8 DPU, FP16 VPU, Edge TPU, CPU).  This
package is the *online* half: a serving fabric that routes live traffic
across those pools and keeps serving through transient device faults —
the operating regime of an onboard vision system in space.

Architecture (one module per concern)::

      requests ──> Router.submit ──────────────────────────────┐
                     │  admission: SLO budgets vs live frontier │ slo.py
                     │  plan pick: cheapest admissible          │
                     │  placement: least-loaded compatible pool │ dispatch.py
                     v                                          │
      ┌─ AcceleratorPool ─┐  ┌─ AcceleratorPool ─┐  ...         │ pool.py
      │ {dpu, vpu}        │  │ {edge_tpu, cpu}   │              │
      │ per-plan queues   │  │ bounded batching  │              │
      │ CostModel/Server  │  │ windows, capacity │              │
      └────────┬──────────┘  └─────────┬─────────┘              │
               v                       v                        │
             completions -> Telemetry (latency/energy/violations) telemetry.py

      PoolFaultInjector (runtime/fault.py) ──> FailoverController  failover.py
        degrade: evict affected requests, reschedule the frontier
        over the surviving profiles, re-dispatch; recover: restore.

Key design points:

* **Pools own profile sets, not single devices.**  A plan is routable to
  a pool iff every profile its segments use survives there — segment
  handoff is a board-level link, so a plan cannot straddle pools.
* **Admission rejects, failover never does.**  An infeasible SLO fails
  fast at submit; a request displaced by a fault is re-dispatched
  best-effort and any deadline miss is *reported* (telemetry violation),
  never silently dropped — matching the paper's companion requirement
  that onboard serving degrades gracefully under SEUs.
* **All plans come from the scheduler.**  Dispatch only ever selects
  from ``schedule()`` / ``reschedule_over_subset()`` output, so every
  routed plan is Pareto-optimal over the currently-live profile subset.

This package is the routing *fabric*; the public serving surface is
``repro.serving`` (FleetSpec -> ServingClient), which assembles routers,
pools, and engine-backed executors from declarative specs — call sites
should not construct :class:`Router` directly.

Demo: ``PYTHONPATH=src python -m repro.launch.route --requests 400``.
Bench: ``PYTHONPATH=src python -m benchmarks.router_bench``.
"""
from repro.router.dispatch import RetryPolicy, Router
from repro.router.failover import FailoverController
from repro.router.pool import (AcceleratorPool, CostModelExecutor,
                               PoolState, RouterRequest)
from repro.router.slo import (SLO_CLASSES, SLOClass, admissible_plans,
                              select_plan)
from repro.router.telemetry import Telemetry

__all__ = [
    "AcceleratorPool", "CostModelExecutor", "FailoverController",
    "PoolState", "RetryPolicy", "Router", "RouterRequest", "SLOClass",
    "SLO_CLASSES",
    "Telemetry", "admissible_plans", "select_plan",
]
