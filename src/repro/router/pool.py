"""Accelerator pool: a group of co-located devices executing routed batches.

A pool owns a *set of accelerator profiles* (e.g. one ZCU104 board is
``{mpsoc_dpu, myriadx_vpu}``) and can host any :class:`ScheduledPlan`
whose segment assignments use only profiles it still has.  Requests queue
per-plan; a bounded batching window groups them (a request waits at most
``max_wait_s`` before its group launches even partially full); up to
``capacity`` batches execute concurrently.

Execution is pluggable:
  * :class:`CostModelExecutor` prices the batch with the roofline cost
    model at its actual size — the pool advances on the router's virtual
    clock.  This is what the failover demo / benchmark use: the routing
    fabric is exercised end-to-end without real boards.
  * :class:`~repro.serving.executor.EngineExecutor` (the serving
    facade) drives a real LM server — the continuous-batching engine or
    the windowed baseline — via the shared non-blocking ``step()`` API
    and reports measured wall latency plus decode telemetry (decode-only
    tokens/s, slot occupancy, OutOfBlocks deferrals).

Health is tri-state: HEALTHY, DEGRADED (lost a strict subset of its
profiles — SEU took a device out), DEAD (nothing survives).  Degrading
evicts every queued and in-flight request whose plan needs a lost
profile; the FailoverController re-dispatches them.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.cost_model import LayerCost
from repro.core.scheduler import (ScheduledPlan, plan_profiles,
                                  price_assignments)
from repro.router.slo import SLOClass
from repro.router.telemetry import PoolCounters


@dataclass
class RouterRequest:
    """One admitted unit of traffic flowing through the router."""
    rid: int
    slo: SLOClass
    arrival_s: float
    payload: Any = None                  # e.g. token prompt for an LM pool
    plan: Optional[ScheduledPlan] = None
    pool: Optional[str] = None
    enqueue_s: float = 0.0
    done_s: Optional[float] = None
    violated: bool = False
    dropped: bool = False
    rerouted: int = 0                    # failover re-dispatch count
    deferred: bool = False               # parked by the orbit energy cap
    # golden-signal SLI stamps (virtual clock; None until observed)
    serve_s: Optional[float] = None      # first batch launch (first wins)
    first_out_s: Optional[float] = None  # first token reached the consumer
    queue_wait_s: Optional[float] = None # total time queued, all launches

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo.max_latency_s


class PoolState(Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DEAD = "dead"


class CostModelExecutor:
    """Price a batch with the roofline model at its actual size."""

    def __init__(self, layers: Sequence[LayerCost]):
        self.layers = list(layers)

    def run(self, plan: ScheduledPlan, requests: Sequence[RouterRequest],
            now: float = 0.0) -> Tuple[float, float]:
        return price_assignments(self.layers, plan, batch=len(requests))


@dataclass
class _InFlightBatch:
    plan: ScheduledPlan
    requests: List[RouterRequest]
    start_s: float
    finish_s: float
    energy_j: float


class AcceleratorPool:
    def __init__(self, name: str, profiles: Iterable[str], executor,
                 capacity: int = 1, max_window: int = 4,
                 max_wait_s: float = 0.02, urgent_priority: int = 2,
                 counters: Optional[PoolCounters] = None,
                 shards: int = 1):
        self.name = name
        self.profiles: Tuple[str, ...] = tuple(profiles)
        self.executor = executor
        self.capacity = capacity
        self.max_window = max_window
        self.max_wait_s = max_wait_s
        self.urgent_priority = urgent_priority
        # decode fan-out width behind this pool's seam (CoProcServer
        # shards): the router's completion estimate scales concurrent
        # batch waves by it, so a sharded pool absorbs load N-wide
        self.shards = shards
        self.state = PoolState.HEALTHY
        self.draining = False            # graceful retirement: no new work
        self.counters = counters if counters is not None else PoolCounters()
        self.tracer = None               # Router wires the shared Tracer
        self._lost: Counter = Counter()        # profile -> overlapping faults
        self._queues: Dict[ScheduledPlan, List[RouterRequest]] = {}
        self._inflight: List[_InFlightBatch] = []

    # ------------------------------------------------------------------
    # capability / load
    # ------------------------------------------------------------------
    @property
    def effective_profiles(self) -> frozenset:
        return frozenset(p for p in self.profiles if not self._lost[p])

    def hosts(self, plan: ScheduledPlan) -> bool:
        """Do this pool's surviving profiles cover ``plan``?  The pure
        capability check — what decides whether *already-held* work can
        keep running here (``degrade`` eviction)."""
        return (self.state is not PoolState.DEAD
                and plan_profiles(plan) <= self.effective_profiles)

    def compatible(self, plan: ScheduledPlan) -> bool:
        """Can this pool take NEW work for ``plan``?  A draining pool
        (graceful retirement) refuses new dispatches but keeps executing
        what it already holds — so this is ``hosts`` minus draining."""
        return not self.draining and self.hosts(plan)

    @property
    def queue_depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def in_flight(self) -> int:
        return sum(len(b.requests) for b in self._inflight)

    @property
    def load(self) -> int:
        """Least-loaded routing key: total requests not yet completed."""
        return self.queue_depth + self.in_flight

    # ------------------------------------------------------------------
    # dispatch side
    # ------------------------------------------------------------------
    def enqueue(self, req: RouterRequest, now: float) -> None:
        assert self.compatible(req.plan), (
            f"pool {self.name} cannot host plan {req.plan.assignments}")
        req.pool = self.name
        req.enqueue_s = now
        self._queues.setdefault(req.plan, []).append(req)
        if self.tracer is not None:
            self.tracer.begin(req.rid, "queue", now, pool=self.name,
                              rerouted=req.rerouted)
        self.counters.dispatched += 1
        self.counters.queue_depth_now = self.queue_depth
        self.counters.load_now = self.load

    def step(self, now: float) -> List[RouterRequest]:
        """Complete due batches, then launch ready windows.  Non-blocking:
        returns the requests completed at this instant (their ``done_s``
        is the batch finish time, not ``now``)."""
        completed: List[RouterRequest] = []
        still = []
        for b in self._inflight:
            if b.finish_s <= now:
                for r in b.requests:
                    r.done_s = b.finish_s
                    completed.append(r)
                    if self.tracer is not None:
                        self.tracer.finish(r.rid, "serve", b.finish_s)
                self.counters.completed += len(b.requests)
            else:
                still.append(b)
        self._inflight = still
        while len(self._inflight) < self.capacity:
            launched = self._launch_ready(now)
            if not launched:
                break
        self.counters.queue_depth.record(self.queue_depth)
        self.counters.queue_depth_now = self.queue_depth
        self.counters.load_now = self.load
        return completed

    def _launch_ready(self, now: float) -> bool:
        ready = None
        for plan, q in self._queues.items():
            if not q:
                continue
            full = len(q) >= self.max_window
            waited = now - q[0].enqueue_s >= self.max_wait_s
            # deadline-tight traffic skips the fill wait: a smaller batch
            # beats a deeper one when the budget is the bottleneck
            urgent = any(r.slo.priority >= self.urgent_priority for r in q)
            if full or waited or urgent:
                # oldest head request first (FIFO across plan groups)
                if ready is None or q[0].enqueue_s < ready[1][0].enqueue_s:
                    ready = (plan, q)
        if ready is None:
            return False
        plan, q = ready
        batch, self._queues[plan] = q[:self.max_window], q[self.max_window:]
        for r in batch:                  # SLI stamps: first launch wins
            if r.serve_s is None:        # for serve_s; queue wait adds up
                r.serve_s = now          # across reroute re-queues
            r.queue_wait_s = (r.queue_wait_s or 0.0) + (now - r.enqueue_s)
        if self.tracer is not None:
            for r in batch:              # queue ends where serve begins
                self.tracer.finish(r.rid, "queue", now)
        lat, energy = self.executor.run(plan, batch, now)
        if self.tracer is not None:
            bid = self.counters.batches
            share = energy / len(batch)  # assigned at launch: eviction
            for r in batch:              # cannot un-spend batch energy
                self.tracer.begin(r.rid, "serve", now, pool=self.name,
                                  bid=f"{self.name}:{bid}",
                                  batch=len(batch), lat_s=lat,
                                  energy_j=share)
        self._inflight.append(_InFlightBatch(plan, batch, now, now + lat,
                                             energy))
        self.counters.batches += 1
        self.counters.batch_size.record(len(batch))
        self.counters.busy_s += lat
        self.counters.energy_j += energy
        return True

    # ------------------------------------------------------------------
    # fault side
    # ------------------------------------------------------------------
    def degrade(self, lost_profiles: Iterable[str]) -> List[RouterRequest]:
        """SEU hit: drop ``lost_profiles`` (all of them when empty) and
        evict every request whose plan can no longer run here.  In-flight
        work on a lost device is destroyed, so those batches evict too."""
        lost = tuple(lost_profiles) or self.profiles
        self._lost.update(lost)
        self.state = (PoolState.DEAD if not self.effective_profiles
                      else PoolState.DEGRADED)
        displaced: List[RouterRequest] = []
        for plan in list(self._queues):
            if not self.hosts(plan):     # NOT compatible(): a draining
                displaced.extend(self._queues.pop(plan))  # pool keeps the
        still = []                       # work the fault didn't touch
        for b in self._inflight:
            if self.hosts(b.plan):
                still.append(b)
            else:
                displaced.extend(b.requests)
        self._inflight = still
        for r in displaced:
            r.pool = None
        self.counters.evicted += len(displaced)
        self.counters.queue_depth_now = self.queue_depth
        self.counters.load_now = self.load
        return displaced

    def recover(self, restored_profiles: Iterable[str]) -> None:
        restored = tuple(restored_profiles) or self.profiles
        self._lost.subtract(restored)
        self._lost += Counter()                # drop zero/negative entries
        self.state = (PoolState.HEALTHY if not +self._lost
                      else PoolState.DEGRADED)
