"""Online failover: pool degradation -> reschedule -> re-dispatch.

The controller consumes :class:`~repro.runtime.fault.PoolFaultInjector`
events on the router's clock.  On a degrade event it:
  1. marks the pool DEGRADED/DEAD and evicts every queued *and in-flight*
     request whose plan needs a lost profile (an SEU destroys in-flight
     work — those requests restart, they are not resumed);
  2. refreshes the router's Pareto frontier over the surviving profile
     subset (``reschedule_over_subset``) so subsequent admissions never
     see a dead device;
  3. re-dispatches each displaced request — best-effort if its SLO is no
     longer satisfiable, dropped (and counted) only when nothing routable
     survives anywhere.

Recover events restore the profiles and refresh the frontier again, so a
transient SEU only narrows the plan space for its scrub window.
"""
from __future__ import annotations

from typing import List, Optional

from repro.runtime.fault import PoolFaultEvent, PoolFaultInjector
from repro.router.dispatch import Router
from repro.router.pool import RouterRequest


class FailoverController:
    def __init__(self, router: Router, injector: PoolFaultInjector):
        self.router = router
        self.injector = injector
        self.events: List[PoolFaultEvent] = []     # applied, for reports
        self.frontier_sizes: List[tuple] = []      # (t, |frontier|) trace
        # data-plane faults (kv_bitflip / slot_stall / handoff_loss) act
        # inside a pool's engine, not on the pool itself; the serving
        # client registers this handler to deliver them — the router
        # layer stays ignorant of engine internals
        self.data_plane = None             # callable(event) or None

    def poll(self, now: float) -> List[RouterRequest]:
        """Apply every fault event due by ``now``; returns the requests
        that were displaced and re-dispatched (for tests/observability)."""
        displaced_total: List[RouterRequest] = []
        for ev in self.injector.poll(now):
            self.events.append(ev)
            if ev.fault.kind != "pool":
                if self.data_plane is not None:
                    self.data_plane(ev)
                continue
            pool = self.router.pools.get(ev.fault.pool)
            if pool is None:
                continue
            if ev.kind == "degrade":
                displaced = pool.degrade(ev.fault.lost_profiles)
                self.router.telemetry.failovers += 1
                self.router.refresh_plans()
                tracer = self.router.telemetry.tracer
                for req in displaced:
                    # the SEU destroyed whatever stage held the request;
                    # close it here (degrade() has no clock) so failover
                    # chains never leak open queue/serve spans
                    tracer.finish(req.rid, "serve", now, evicted=True)
                    tracer.finish(req.rid, "queue", now, evicted=True)
                    tracer.event("failover", now, rid=req.rid,
                                 pool=ev.fault.pool,
                                 lost=list(ev.fault.lost_profiles))
                    self.router.redispatch(req, now)
                displaced_total.extend(displaced)
            elif ev.kind == "recover":
                pool.recover(ev.fault.lost_profiles)
                self.router.refresh_plans()
            self.frontier_sizes.append((now, len(self.router.frontier)))
        return displaced_total

    @property
    def pending_faults(self) -> int:
        return self.injector.pending
