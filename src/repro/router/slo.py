"""Request SLO classes — the contract a dispatch decision must satisfy.

A space-segment serving stack does not have one latency target: a
downlink-critical pose estimate feeding the attitude loop has a hard
deadline and a tight accuracy budget, while background science imagery
can wait seconds but must sip energy.  An :class:`SLOClass` captures the
three axes the Pareto scheduler already prices — latency, energy,
accuracy penalty — as per-request *budgets*, and plan selection becomes
``best_under_accuracy``-style filtering of the live frontier.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.scheduler import ScheduledPlan


@dataclass(frozen=True)
class SLOClass:
    name: str
    max_latency_s: float                 # end-to-end deadline per request
    max_energy_j: float = math.inf       # per-inference energy budget
    max_accuracy_penalty: float = math.inf  # tolerated error-budget units
    priority: int = 0                    # higher preempts at equal deadline

    def admits(self, plan: ScheduledPlan) -> bool:
        """Does this plan's *nominal* cost fit the budgets?  (Queueing and
        batching can still push a served request over — that is recorded
        as a violation, not prevented at admission.)"""
        return (plan.latency_s <= self.max_latency_s
                and plan.energy_j <= self.max_energy_j
                and plan.accuracy_penalty <= self.max_accuracy_penalty)


# The demo/benchmark traffic mix.  Budgets are sized for the paper's
# UrsoNet-on-board operating points (DPU ~53 ms, VPU ~246 ms, Table I).
DOWNLINK_CRITICAL = SLOClass("downlink-critical", max_latency_s=0.12,
                             max_accuracy_penalty=0.10, priority=2)
REALTIME_TRACKING = SLOClass("realtime-tracking", max_latency_s=0.40,
                             max_energy_j=5.0, max_accuracy_penalty=0.30,
                             priority=1)
BACKGROUND_SCIENCE = SLOClass("background-science", max_latency_s=3.0,
                              max_energy_j=1.5, max_accuracy_penalty=0.60)
BULK_REPROCESS = SLOClass("bulk-reprocess", max_latency_s=15.0,
                          max_energy_j=1.0)

SLO_CLASSES: Dict[str, SLOClass] = {
    s.name: s for s in (DOWNLINK_CRITICAL, REALTIME_TRACKING,
                        BACKGROUND_SCIENCE, BULK_REPROCESS)
}


def admissible_plans(plans: Sequence[ScheduledPlan],
                     slo: SLOClass) -> List[ScheduledPlan]:
    return [p for p in plans if slo.admits(p)]


def select_plan(plans: Sequence[ScheduledPlan], slo: SLOClass,
                latency_headroom: float = 1.0) -> Optional[ScheduledPlan]:
    """Cheapest admissible plan at *nominal* (load-free) cost: minimize
    energy (the scarce resource on orbit), tie-break on latency then
    accuracy.  ``None`` = infeasible — nothing meets the budgets even on
    an idle fleet.  ``Router._choose`` applies this same policy but with
    a queue-wait completion estimate in place of nominal latency; this
    load-free form is the reference policy (and what capacity planning /
    tests use).

    ``latency_headroom`` < 1 prefers plans whose latency fits in that
    fraction of the budget, leaving slack for batching and queueing; a
    plan that only fits the full budget is still admissible (better to
    serve tight than reject), it just loses the preference.
    """
    ok = admissible_plans(plans, slo)
    if not ok:
        return None
    slack = [p for p in ok
             if p.latency_s <= latency_headroom * slo.max_latency_s]
    return min(slack or ok, key=lambda p: (p.energy_j, p.latency_s,
                                           p.accuracy_penalty))
