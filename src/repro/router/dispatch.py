"""Admission control + SLO-aware dispatch over heterogeneous pools.

Per request the router:
  1. filters the *live* Pareto frontier (recomputed whenever the set of
     healthy profiles changes) down to plans whose nominal cost fits the
     request's SLO budgets AND that at least one live pool can host;
  2. estimates end-to-end completion per candidate (plan, pool) — nominal
     plan latency plus a queue-wait term from the pool's current load —
     and rejects the request at admission when no estimate fits the
     deadline: infeasible budgets AND hopeless overload fail fast instead
     of rotting in a queue;
  3. otherwise picks the cheapest surviving plan (min energy, preferring
     candidates with latency slack) and routes it to the least-loaded
     compatible pool, where it batches inside a bounded window.

The queue-wait term is what keeps a cheap plan from becoming a magnet: a
frontier plan that funnels to one congested pool loses to a slightly
dearer plan on an idle pool as soon as the wait estimate breaks its SLO.

The router never invents plans: everything it dispatches comes from
``core.scheduler.schedule`` / ``reschedule_over_subset``, so a dispatched
plan is Pareto-optimal over the surviving profile set by construction —
a property the test suite checks directly.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.cost_model import LayerCost
from repro.core.scheduler import ScheduledPlan, reschedule_over_subset
from repro.router.pool import AcceleratorPool, PoolState, RouterRequest
from repro.router.slo import SLOClass
from repro.router.telemetry import Telemetry

_EPS = 1e-9


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded failover redispatch, per SLO class.

    The first redispatch after an eviction is immediate (a healthy
    fleet should re-place displaced work the same tick); every further
    attempt waits ``backoff_s * multiplier**(attempt-2)`` on the virtual
    clock, capped at ``max_backoff_s``, so a flapping pool cannot spin
    the router hot.  Past ``max_attempts`` the request drops with the
    ``retry_exhausted`` reason code instead of retrying forever.
    ``give_up_past_deadline`` optionally drops a queued retry whose
    deadline already passed (``deadline`` reason) rather than serving it
    best-effort."""
    max_attempts: int = 5
    backoff_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    give_up_past_deadline: bool = False

    def delay_s(self, attempt: int) -> float:
        """Backoff before redispatch ``attempt`` (2nd and later)."""
        return min(self.backoff_s * self.multiplier ** max(attempt - 2, 0),
                   self.max_backoff_s)


class Router:
    def __init__(self, layers: Sequence[LayerCost],
                 pools: Sequence[AcceleratorPool],
                 batch: int = 1, max_segments: int = 2,
                 accuracy_penalty: Optional[Dict[str, float]] = None,
                 cut_candidates: Optional[Sequence[int]] = None,
                 latency_headroom: float = 0.6,
                 telemetry: Optional[Telemetry] = None):
        if not pools:
            raise ValueError("router needs at least one pool")
        self.layers = list(layers)
        self.latency_headroom = latency_headroom
        # "nominal" | "conserve" — set by the orbit FleetController when
        # the global energy bucket runs low; flips plan selection from
        # latency-slack-first to energy-first (see _choose)
        self.energy_mode = "nominal"
        self.pools: Dict[str, AcceleratorPool] = {p.name: p for p in pools}
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        for p in pools:                    # pool counters live in telemetry
            self.telemetry.pools[p.name] = p.counters
            p.tracer = self.telemetry.tracer
            p.executor.tracer = self.telemetry.tracer
            p.executor.pool_name = p.name
        self._sched_kw = dict(batch=batch, max_segments=max_segments,
                              accuracy_penalty=accuracy_penalty,
                              cut_candidates=cut_candidates)
        # bounded failover retries: per-SLO-class overrides on top of one
        # default policy; queued (backed-off) retries wait on this heap
        # and drain in step(now)
        self.default_retry = RetryPolicy()
        self.retry_policies: Dict[str, RetryPolicy] = {}
        self._retries: List[Tuple[float, int, RouterRequest]] = []
        self._retry_seq = 0
        self.all_profiles = sorted({prof for p in pools
                                    for prof in p.profiles})
        self.frontier: List[ScheduledPlan] = []
        self.refresh_plans(count=False)

    # ------------------------------------------------------------------
    # plan management
    # ------------------------------------------------------------------
    def available_profiles(self) -> Set[str]:
        out: Set[str] = set()
        for p in self.pools.values():
            if p.state is not PoolState.DEAD:
                out |= p.effective_profiles
        return out

    def refresh_plans(self, count: bool = True) -> None:
        """Recompute the frontier over the surviving profile subset."""
        avail = self.available_profiles()
        lost = [p for p in self.all_profiles if p not in avail]
        self.frontier = reschedule_over_subset(
            self.layers, self.all_profiles, lost=lost, **self._sched_kw)
        if count:
            self.telemetry.reschedules += 1

    # ------------------------------------------------------------------
    # live fleet mutation (the orbit autoscaler's seam)
    # ------------------------------------------------------------------
    def add_pool(self, pool: AcceleratorPool) -> None:
        """Join a pool to the live fleet and refresh the frontier over the
        widened profile set.  Names must be fresh — a name that collides
        with a live pool is an error, and reusing a *retired* name would
        splice two pools' telemetry histories (the autoscaler's monotonic
        clone suffixes guarantee freshness)."""
        if pool.name in self.pools:
            raise ValueError(f"pool {pool.name!r} is already routed")
        self.pools[pool.name] = pool
        self.telemetry.pools[pool.name] = pool.counters
        pool.tracer = self.telemetry.tracer
        pool.executor.tracer = self.telemetry.tracer
        pool.executor.pool_name = pool.name
        merged = sorted(set(self.all_profiles) | set(pool.profiles))
        self.all_profiles = merged
        self.refresh_plans()

    def register_stage_pool(self, name: str, counters):
        """Attach telemetry counters for a co-processing *stage* pool —
        e.g. the prefill-class engine feeding a disaggregated decode
        pool.  Stage pools never take independent dispatches (the owning
        executor charges their share of each routed batch — tokens,
        busy time, energy — directly into these counters), but they
        appear in the fleet snapshot like any routed pool, so the orbit
        energy bucket drains against per-stage spend and monitors see
        the DPU/VPU split.

        Returns the counters object actually registered: re-adding a
        stage name whose pool retired earlier *continues* the retired
        counters (callers must charge the returned object), because the
        fleet's cumulative ``energy_j`` must stay monotone for the
        orbit bucket — the same history-splicing ``remove_pool`` keeps
        for routed pools."""
        if name in self.pools:
            raise ValueError(f"pool name {name!r} is already routed")
        if name in self.telemetry.pools:
            return self.telemetry.pools[name]
        self.telemetry.pools[name] = counters
        return counters

    def remove_pool(self, name: str) -> AcceleratorPool:
        """Detach a drained pool (graceful retirement's final step).  The
        pool must be empty — callers mark it ``draining`` and wait for its
        load to reach zero, so no queued or in-flight request is ever
        dropped.  Its counters stay in telemetry as history (and keep the
        fleet's cumulative ``energy_j`` monotone for the energy bucket)."""
        pool = self.pools.get(name)
        if pool is None:
            raise KeyError(f"no pool named {name!r}")
        if pool.load:
            raise ValueError(f"pool {name!r} still holds {pool.load} "
                             f"requests; drain before removing")
        if len(self.pools) == 1:
            raise ValueError("cannot remove the last pool in the fleet")
        del self.pools[name]
        self.refresh_plans()
        return pool

    def routable_plans(self) -> List[ScheduledPlan]:
        """Frontier plans some live pool can actually host.  (A frontier
        plan can be unroutable when its profiles survive only split
        across pools — segments hand off over a board-level link, not the
        network.)"""
        pools = self.pools.values()
        return [pl for pl in self.frontier
                if any(p.compatible(pl) for p in pools)]

    # ------------------------------------------------------------------
    # admission + dispatch
    # ------------------------------------------------------------------
    def _estimate_completion(self, plan: ScheduledPlan,
                             pool: AcceleratorPool) -> float:
        """Rough end-to-end estimate: the pool's backlog forms
        ceil(load+1 / window) batches draining over ``capacity`` slots
        (times the decode fan-out width for sharded pools — N importers
        absorb N batches per wave), each taking about one nominal plan
        latency."""
        batches = math.ceil((pool.load + 1) / pool.max_window)
        waves = math.ceil(batches / (pool.capacity
                                     * getattr(pool, "shards", 1)))
        return waves * plan.latency_s

    def _best_pool(self, plan: ScheduledPlan
                   ) -> Optional[Tuple[AcceleratorPool, float]]:
        """Compatible pool with the best completion estimate (capacity and
        window differ across pools, so least-loaded is not least-wait)."""
        cands = [(self._estimate_completion(plan, p), p.load, p.name, p)
                 for p in self.pools.values() if p.compatible(plan)]
        if not cands:
            return None
        est, _, _, pool = min(cands, key=lambda c: c[:3])
        return pool, est

    def _choose(self, slo: SLOClass
                ) -> Optional[Tuple[ScheduledPlan, AcceleratorPool]]:
        """Best (plan, pool): cheapest energy whose completion estimate
        fits the deadline, preferring candidates with latency slack.  In
        ``energy_mode == "conserve"`` (orbit bucket running low) the
        preference order inverts: joules dominate outright, and a slower
        in-budget plan beats a faster dearer one — the fleet trades
        latency slack for battery."""
        best = best_key = None
        conserve = self.energy_mode == "conserve"
        for plan in self.frontier:
            if not slo.admits(plan):
                continue
            placed = self._best_pool(plan)
            if placed is None:
                continue
            pool, est = placed
            if est > slo.max_latency_s:
                continue
            slack = est <= self.latency_headroom * slo.max_latency_s
            key = ((plan.energy_j, not slack, est, plan.accuracy_penalty)
                   if conserve
                   else (not slack, plan.energy_j, est,
                         plan.accuracy_penalty))
            if best_key is None or key < best_key:
                best_key, best = key, (plan, pool)
        return best

    def submit(self, req: RouterRequest, now: float) -> bool:
        """Admit and dispatch, or reject (returns False) when no routable
        plan can meet the request's SLO budgets at current load."""
        choice = self._choose(req.slo)
        if choice is None:
            self.telemetry.record_rejection(req.slo.name, now)
            self.telemetry.tracer.end_request(req.rid, now, "rejected",
                                              slo=req.slo.name)
            return False
        self._dispatch(req, *choice, now)
        self.telemetry.admitted += 1
        return True

    def retry_policy_for(self, slo_name: str) -> RetryPolicy:
        return self.retry_policies.get(slo_name, self.default_retry)

    def redispatch(self, req: RouterRequest, now: float) -> None:
        """Failover path, under the request's class
        :class:`RetryPolicy`: the first redispatch is immediate, later
        ones wait out an exponential backoff on the virtual clock, and
        the attempt budget is hard — a request that keeps getting
        displaced drops with ``retry_exhausted`` instead of looping."""
        req.rerouted += 1
        policy = self.retry_policy_for(req.slo.name)
        if req.rerouted > policy.max_attempts:
            self._drop(req, now, "retry_exhausted")
            return
        self.telemetry.retries += 1
        self.telemetry.slis.observe_retry(now, req.slo.name, req.pool)
        if req.rerouted == 1:
            self._redispatch_now(req, now)
            return
        heapq.heappush(self._retries,
                       (now + policy.delay_s(req.rerouted),
                        self._retry_seq, req))
        self._retry_seq += 1

    def _redispatch_now(self, req: RouterRequest, now: float) -> None:
        """One redispatch attempt: the request is already admitted, so it
        is never re-rejected — if no surviving plan fits its SLO we still
        serve it best-effort (fastest surviving estimate) and let
        completion record the violation.  Only a total loss (nothing
        routable) drops it."""
        choice = self._choose(req.slo)
        if choice is None:
            cands = []
            for plan in self.routable_plans():
                pool, est = self._best_pool(plan)
                cands.append((est, plan.energy_j, plan, pool))
            if cands:
                est, _, plan, pool = min(cands, key=lambda c: c[:2])
                choice = (plan, pool)
        if choice is None:
            self._drop(req, now, "no_route")
            return
        self._dispatch(req, *choice, now)

    def _drop(self, req: RouterRequest, now: float, reason: str) -> None:
        req.dropped = True
        req.violated = True
        self.telemetry.record_drop(req.slo.name, reason, t=now,
                                   pool=req.pool)
        self.telemetry.tracer.end_request(req.rid, now, "dropped",
                                          rerouted=req.rerouted,
                                          reason=reason)

    def _dispatch(self, req: RouterRequest, plan: ScheduledPlan,
                  pool: AcceleratorPool, now: float) -> None:
        req.plan = plan
        pool.enqueue(req, now)

    # ------------------------------------------------------------------
    # progress
    # ------------------------------------------------------------------
    def step(self, now: float) -> List[RouterRequest]:
        """Advance every pool one tick; record completions + violations."""
        while self._retries and self._retries[0][0] <= now:
            _, _, req = heapq.heappop(self._retries)
            policy = self.retry_policy_for(req.slo.name)
            if policy.give_up_past_deadline and now > req.deadline_s + _EPS:
                self._drop(req, now, "deadline")
                continue
            self._redispatch_now(req, now)
        completed: List[RouterRequest] = []
        for pool in self.pools.values():
            completed.extend(pool.step(now))
        tracer = self.telemetry.tracer
        for r in completed:
            r.violated = r.done_s > r.deadline_s + _EPS
            if r.first_out_s is None:
                # hook-less (cost-model) pools deliver everything at
                # completion, so TTFT degenerates to the e2e latency
                r.first_out_s = r.done_s
            out = getattr(r.payload, "output", None)
            n_out = 0 if out is None else len(out)
            itl = ((r.done_s - r.first_out_s) / (n_out - 1)
                   if n_out > 1 else None)
            self.telemetry.record_completion(r.slo.name,
                                             r.done_s - r.arrival_s,
                                             r.violated, t=r.done_s,
                                             pool=r.pool,
                                             ttft_s=(r.first_out_s
                                                     - r.arrival_s),
                                             itl_s=itl,
                                             queue_wait_s=r.queue_wait_s)
            tracer.end_request(r.rid, r.done_s, "completed",
                               violated=r.violated, pool=r.pool)
        return completed

    @property
    def outstanding(self) -> int:
        # backed-off retries are still live, owed work
        return sum(p.load for p in self.pools.values()) + len(self._retries)
