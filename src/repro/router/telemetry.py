"""Router telemetry: per-pool counters and latency/queue histograms.

Everything is plain Python (no jax) and JSON-serializable via
``snapshot()`` — the same dict feeds the launch demo's report, the
benchmark's output file, and the tests' assertions.  Histograms keep raw
samples rather than buckets, bounded by *reservoir sampling*: beyond
``max_samples`` each new value replaces a uniformly-random retained one
(deterministic seed), so long runs keep an unbiased sample of the whole
run instead of a snapshot of its first N values.  Percentile queries
sort once and reuse the sorted view until the next ``record`` (a
dirty-flag cache — ``snapshot()`` asks for several percentiles per
histogram).
"""
from __future__ import annotations

import random
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Histogram:
    samples: List[float] = field(default_factory=list)
    max_samples: int = 100_000            # bound memory on long runs
    seed: int = 0x5EED                    # reservoir RNG (deterministic)
    _seen: int = field(default=0, repr=False, compare=False)
    _dirty: bool = field(default=True, repr=False, compare=False)
    _sorted: Optional[List[float]] = field(default=None, repr=False,
                                           compare=False)
    _rng: Optional[random.Random] = field(default=None, repr=False,
                                          compare=False)

    def __post_init__(self):
        self._seen = len(self.samples)

    def record(self, v: float) -> None:
        """Record one sample.  Past ``max_samples`` the reservoir kicks
        in (Vitter's algorithm R): the new value replaces a uniformly
        random retained one with probability ``max_samples/seen``, so
        p50/p99 stay representative of the *whole* run — the old
        keep-the-first-N policy biased every percentile toward run
        start the moment the bound was hit."""
        self._seen += 1
        self._dirty = True
        if len(self.samples) < self.max_samples:
            self.samples.append(float(v))
            return
        if self._rng is None:
            self._rng = random.Random(self.seed)
        j = self._rng.randrange(self._seen)
        if j < self.max_samples:
            self.samples[j] = float(v)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        if (self._dirty or self._sorted is None
                or len(self._sorted) != len(self.samples)):
            self._sorted = sorted(self.samples)
            self._dirty = False
        s = self._sorted
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    @property
    def count(self) -> int:
        """Samples *recorded* (dropped ones included)."""
        return self._seen

    @property
    def dropped(self) -> int:
        """Samples recorded but not retained by the reservoir."""
        return self._seen - len(self.samples)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean": round(self.mean, 6),
                "p50": round(self.percentile(50), 6),
                "p99": round(self.percentile(99), 6),
                "dropped": self.dropped}


@dataclass
class PoolCounters:
    dispatched: int = 0                   # requests routed to the pool
    completed: int = 0
    evicted: int = 0                      # displaced by a fault
    batches: int = 0
    energy_j: float = 0.0                 # cost-model energy estimate
    busy_s: float = 0.0                   # time spent executing batches
    tokens_generated: int = 0             # LM pools: real sampled tokens
    decode_tokens: int = 0                # tokens from decode steps only
    decode_s: float = 0.0                 # wall time inside decode steps
    prefill_tokens: int = 0               # prompt tokens prefilled here
    deferrals: int = 0                    # OutOfBlocks admission deferrals
    queue_depth_now: int = 0              # live queue depth (this instant)
    load_now: int = 0                     # live queued + in-flight
    bitflips_detected: int = 0            # KV checksum mismatches caught
    blocks_quarantined: int = 0           # KV blocks pulled from service
    watchdog_trips: int = 0               # stalled slots evicted
    handoffs_replayed: int = 0            # lost/corrupt handoffs re-run
    prefix_hits: int = 0                  # prompt blocks served by the
                                          # content-hash prefix index
    prefix_lookups: int = 0               # prompt blocks offered to it
    # sharded decode pools: handoff imports per consumer shard
    imports_by_shard: Dict[str, int] = field(default_factory=dict)
    queue_depth: Histogram = field(default_factory=Histogram)
    batch_size: Histogram = field(default_factory=Histogram)
    slot_occupancy: Histogram = field(default_factory=Histogram)

    @property
    def tokens_per_s(self) -> float:
        """Throughput over time spent executing batches end-to-end
        (prefill, admission stalls, and decode all included)."""
        return self.tokens_generated / self.busy_s if self.busy_s else 0.0

    @property
    def decode_tokens_per_s(self) -> float:
        """Decode-only throughput: sampled decode tokens over wall time
        inside decode steps — the number ``benchmarks/decode_bench.py``
        reports, free of prefill-window idle time and prompt tokens."""
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of offered prompt blocks served from the shared
        prefix index (0.0 when the pool never offered one)."""
        return (self.prefix_hits / self.prefix_lookups
                if self.prefix_lookups else 0.0)

    def summary(self) -> Dict:
        return {"dispatched": self.dispatched, "completed": self.completed,
                "evicted": self.evicted, "batches": self.batches,
                "energy_j": round(self.energy_j, 4),
                "busy_s": round(self.busy_s, 4),
                "tokens_generated": self.tokens_generated,
                "tokens_per_s": round(self.tokens_per_s, 2),
                "decode_tokens": self.decode_tokens,
                "decode_s": round(self.decode_s, 4),
                "decode_tokens_per_s": round(self.decode_tokens_per_s, 2),
                "prefill_tokens": self.prefill_tokens,
                "deferrals": self.deferrals,
                "queue_depth_now": self.queue_depth_now,
                "load_now": self.load_now,
                "bitflips_detected": self.bitflips_detected,
                "blocks_quarantined": self.blocks_quarantined,
                "watchdog_trips": self.watchdog_trips,
                "handoffs_replayed": self.handoffs_replayed,
                "prefix_hits": self.prefix_hits,
                "prefix_lookups": self.prefix_lookups,
                "prefix_hit_rate": round(self.prefix_hit_rate, 4),
                "imports_by_shard": dict(sorted(
                    self.imports_by_shard.items())),
                "queue_depth": self.queue_depth.summary(),
                "batch_size": self.batch_size.summary(),
                "slot_occupancy": self.slot_occupancy.summary()}


class Telemetry:
    """One instance per Router; pools and the failover controller write
    into it, reports read from it.

    Also hosts the fleet's flight recorder: ``tracer`` is the one
    :class:`~repro.obs.trace.Tracer` every layer shares (disabled by
    default — span recording costs one attribute check until
    ``ServingClient.enable_tracing()`` flips it on)."""

    def __init__(self):
        from repro.obs.trace import Tracer   # local: obs imports telemetry
        from repro.obs.slo import AlertBus, SLIRegistry
        self.tracer = Tracer()
        # golden-signal SLIs + SLO alert state: always constructed so
        # snapshot()["slis"] / ["alerts"] have a stable shape whether or
        # not an SLOSpec is ever attached
        self.slis = SLIRegistry()
        self.alerts = AlertBus()
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.violations = 0
        self.dropped = 0                  # admitted but unservable (no pool)
        # why admitted requests were dropped, zero-initialized so the
        # snapshot schema is stable whether or not a reason ever fires
        self.drops_by_reason: Dict[str, int] = {
            "no_route": 0, "retry_exhausted": 0, "dry_battery": 0,
            "deadline": 0}
        self.failovers = 0
        self.reschedules = 0
        self.retries = 0                  # bounded redispatch attempts
        self.watchdog_trips = 0           # client-level no-progress trips
        self.energy_deferred = 0          # parked by the orbit energy cap
        self.energy_rejected = 0          # rejected with the battery dry
        self.pools_added = 0              # autoscaler / live growth events
        self.pools_retired = 0            # graceful retirements completed
        self.pools: Dict[str, PoolCounters] = defaultdict(PoolCounters)
        self.latency_by_class: Dict[str, Histogram] = defaultdict(Histogram)
        self.violations_by_class: Dict[str, int] = defaultdict(int)

    def pool(self, name: str) -> PoolCounters:
        return self.pools[name]

    def record_completion(self, slo_name: str, latency_s: float,
                          violated: bool, *, t: Optional[float] = None,
                          pool: Optional[str] = None,
                          ttft_s: Optional[float] = None,
                          itl_s: Optional[float] = None,
                          queue_wait_s: Optional[float] = None) -> None:
        self.completed += 1
        self.latency_by_class[slo_name].record(latency_s)
        if violated:
            self.violations += 1
            self.violations_by_class[slo_name] += 1
        if t is not None:
            # same terminal path that closes the span chain also feeds
            # the golden-signal SLIs — no second instrumentation layer
            self.slis.observe_completion(t, slo_name, pool, latency_s,
                                         ttft_s=ttft_s, itl_s=itl_s,
                                         queue_wait_s=queue_wait_s,
                                         violated=violated)

    def record_rejection(self, slo_name: str, t: float) -> None:
        """Admission-time rejection (router gate or dry-battery energy
        gate): one counter bump plus the SLI/burn-window event."""
        self.rejected += 1
        self.slis.observe_reject(t, slo_name)

    def record_drop(self, slo_name: str, reason: str = "no_route",
                    admitted: bool = True, *, t: Optional[float] = None,
                    pool: Optional[str] = None) -> None:
        """Count one dropped request under its reason code.  A drop at
        the admission gate itself (``admitted=False`` — e.g. dry-battery
        rejection) keeps the reason ledger without inflating the
        admitted-request ``dropped`` counter the accounting invariant
        (admitted == completed + dropped) is checked against."""
        self.drops_by_reason[reason] = (
            self.drops_by_reason.get(reason, 0) + 1)
        if not admitted:
            return
        self.dropped += 1
        self.violations += 1
        self.violations_by_class[slo_name] += 1
        if t is not None:
            self.slis.observe_drop(t, slo_name, pool)

    def snapshot(self) -> Dict:
        return {
            "admitted": self.admitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "violations": self.violations,
            "dropped": self.dropped,
            "drops_by_reason": dict(sorted(self.drops_by_reason.items())),
            "failovers": self.failovers,
            "reschedules": self.reschedules,
            "retries": self.retries,
            "watchdog_trips": self.watchdog_trips,
            # fleet-wide hardening aggregates (sums of the per-pool
            # counters, so a dashboard needs one read)
            "bitflips_detected": sum(p.bitflips_detected
                                     for p in self.pools.values()),
            "blocks_quarantined": sum(p.blocks_quarantined
                                      for p in self.pools.values()),
            "handoffs_replayed": sum(p.handoffs_replayed
                                     for p in self.pools.values()),
            # prefix-sharing efficiency across the fleet: hit rate of
            # the content-hash block index (pooled, not averaged)
            "prefix_hits": sum(p.prefix_hits for p in self.pools.values()),
            "prefix_hit_rate": round(
                (sum(p.prefix_hits for p in self.pools.values())
                 / max(1, sum(p.prefix_lookups
                              for p in self.pools.values()))), 4),
            "energy_deferred": self.energy_deferred,
            "energy_rejected": self.energy_rejected,
            "pools_added": self.pools_added,
            "pools_retired": self.pools_retired,
            # fleet-wide aggregates: the one schema the orbit controller
            # and external monitors read (retired pools included, so
            # energy_j is the cumulative orbit spend)
            "energy_j": round(sum(p.energy_j for p in self.pools.values()),
                              4),
            "queue_depth": sum(p.queue_depth_now
                               for p in self.pools.values()),
            "pools": {k: v.summary() for k, v in sorted(self.pools.items())},
            "latency_by_class": {k: v.summary() for k, v in
                                 sorted(self.latency_by_class.items())},
            "violations_by_class": dict(sorted(
                self.violations_by_class.items())),
            # golden-signal SLIs + SLO alert state (repro.obs.slo)
            "slis": self.slis.summary(),
            "alerts": self.alerts.snapshot(),
        }
