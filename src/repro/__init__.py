"""repro — MPAI (heterogeneous mixed-precision co-processing) as a
production JAX/TPU framework.  See README.md / DESIGN.md.

Public API surface:

    from repro import configs                  # --arch registry
    from repro.core.partition import PartitionPlan
    from repro.core.scheduler import schedule
    from repro.core import qat                 # train/serve plan lifecycle
    from repro.models import transformer       # forward / decode / loss
    from repro.runtime.train_loop import Trainer
    from repro.serving import FleetSpec, PoolSpec           # fleets as data
    from repro.serving import ServingClient                 # the front door

Serving goes through ``repro.serving`` (FleetSpec -> ServingClient ->
ResponseHandle.stream()); the decode engine and windowed baseline in
``repro.runtime.serve`` are internal to it.
"""

__version__ = "1.0.0"
