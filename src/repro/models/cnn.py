"""UrsoNet — the paper's benchmark workload (satellite pose estimation,
Proenca & Gao, ICRA 2020) — plus analytic layer tables for the Fig. 2
networks (MobileNetV2 / ResNet-50 / InceptionV4) used by the cost model.

UrsoNet here: ResNet-style backbone (stem + 4 stages of residual blocks,
GroupNorm) with two heads — location regression [3] and orientation
quaternion [4].  The MPAI partition splits exactly where the paper does:
convolutional backbone -> INT8 engine, FC heads -> FP16/bf16 engine.

Convolutions honour the precision policy by quantize->dequantize of weights
and activations around ``lax.conv`` (int8-simulated numerics; the true-int8
MXU path is exercised by the matmul kernel — DESIGN.md §9).
"""
from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import fake_quant, pdot, quantize
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# Policy-aware conv
# ---------------------------------------------------------------------------
def pconv(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
          policy: PrecisionPolicy = DEFAULT_POLICY) -> jnp.ndarray:
    """x: [B,H,W,Cin]; w: [kh,kw,Cin,Cout] — NHWC/HWIO."""
    if policy.mode == "fake":
        w = fake_quant(w, channel_axis=-1)
        x = fake_quant(x)
    elif policy.mode == "quant":
        w = quantize(w, channel_axis=-1).dequantize(jnp.bfloat16)
        x = quantize(x).dequantize(jnp.bfloat16)
    dt = policy.precision.compute_dtype
    return jax.lax.conv_general_dilated(
        x.astype(dt), w.astype(dt), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(params: Dict, x: jnp.ndarray, groups: int = 8,
               eps: float = 1e-5) -> jnp.ndarray:
    b, h, w, c = x.shape
    g = math.gcd(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(b, h, w, c) * params["scale"] + params["bias"]).astype(x.dtype)


def _gn_init(c: int) -> Dict:
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _conv_init(key, kh, kw, cin, cout) -> jnp.ndarray:
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) / math.sqrt(fan)


# ---------------------------------------------------------------------------
# UrsoNet
# ---------------------------------------------------------------------------
class UrsoNetConfig(NamedTuple):
    name: str = "ursonet"
    image_hw: Tuple[int, int] = (192, 256)      # backbone input (paper resamples 1280x960)
    widths: Tuple[int, ...] = (32, 64, 128, 256)
    blocks_per_stage: int = 2
    fc_dim: int = 256

    @property
    def num_layers(self) -> int:                 # conv stages as "layers"
        return len(self.widths) * self.blocks_per_stage


def ursonet_init(key, cfg: UrsoNetConfig) -> Dict:
    ks = iter(jax.random.split(key, 64))
    p: Dict = {"stem": {"w": _conv_init(next(ks), 7, 7, 3, cfg.widths[0]),
                        "gn": _gn_init(cfg.widths[0])},
               "stages": []}
    cin = cfg.widths[0]
    for w_ in cfg.widths:
        stage = []
        for b in range(cfg.blocks_per_stage):
            blk = {"w1": _conv_init(next(ks), 3, 3, cin, w_),
                   "gn1": _gn_init(w_),
                   "w2": _conv_init(next(ks), 3, 3, w_, w_),
                   "gn2": _gn_init(w_)}
            if cin != w_:
                blk["proj"] = _conv_init(next(ks), 1, 1, cin, w_)
            stage.append(blk)
            cin = w_
        p["stages"].append(stage)
    p["fc"] = dense_init(next(ks), cin, cfg.fc_dim)
    p["head_loc"] = dense_init(next(ks), cfg.fc_dim, 3)
    p["head_ori"] = dense_init(next(ks), cfg.fc_dim, 4)
    return p


def ursonet_apply(params: Dict, cfg: UrsoNetConfig, images: jnp.ndarray,
                  backbone_policy: PrecisionPolicy = DEFAULT_POLICY,
                  head_policy: PrecisionPolicy = DEFAULT_POLICY
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """images: [B, H, W, 3] -> (loc [B,3], quat [B,4] normalized).

    ``backbone_policy`` / ``head_policy`` are the MPAI partition: conv
    backbone on the INT8 engine, FC heads on the high-precision engine.
    """
    x = images.astype(jnp.bfloat16)
    x = pconv(x, params["stem"]["w"], 2, backbone_policy)
    x = jax.nn.silu(group_norm(params["stem"]["gn"], x))
    for si, stage in enumerate(params["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if bi == 0 and si > 0 else 1
            h = pconv(x, blk["w1"], stride, backbone_policy)
            h = jax.nn.silu(group_norm(blk["gn1"], h))
            h = pconv(h, blk["w2"], 1, backbone_policy)
            h = group_norm(blk["gn2"], h)
            sc = x
            if "proj" in blk:
                sc = pconv(x, blk["proj"], 1, backbone_policy)
            if stride == 2:
                sc = sc[:, ::2, ::2]
            x = jax.nn.silu(h + sc)
    feat = jnp.mean(x, axis=(1, 2))                      # global average pool
    feat = jax.nn.silu(pdot(feat, params["fc"], head_policy))
    loc = pdot(feat, params["head_loc"], head_policy).astype(jnp.float32)
    loc = loc * LOC_SCALE + LOC_OFFSET          # normalized head -> meters
    q = pdot(feat, params["head_ori"], head_policy).astype(jnp.float32)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-8)
    return loc, q


# location normalization (matches the synthetic task's pose distribution)
LOC_OFFSET = jnp.array([0.0, 0.0, 16.0], jnp.float32)
LOC_SCALE = jnp.array([3.0, 2.0, 8.0], jnp.float32)


def pose_loss(loc, q, loc_gt, q_gt) -> jnp.ndarray:
    l_loc = jnp.mean(jnp.sum(jnp.square(
        (loc - loc_gt) / LOC_SCALE), axis=-1))
    dot = jnp.sum(q * q_gt, axis=-1)
    l_ori = jnp.mean(1.0 - jnp.square(dot))
    return l_loc + 4.0 * l_ori


def pose_metrics(loc, q, loc_gt, q_gt) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(LOCE meters, ORIE degrees) — the paper's Table I metrics."""
    loce = jnp.mean(jnp.linalg.norm(loc - loc_gt, axis=-1))
    dot = jnp.clip(jnp.abs(jnp.sum(q * q_gt, axis=-1)), 0.0, 1.0)
    orie = jnp.mean(2.0 * jnp.arccos(dot)) * 180.0 / jnp.pi
    return loce, orie


# ---------------------------------------------------------------------------
# Analytic conv tables for Fig. 2 (cost-model inputs; not executed)
# ---------------------------------------------------------------------------
class ConvLayerSpec(NamedTuple):
    name: str
    macs: float           # multiply-accumulates per image
    params: float         # weights
    activations: float    # output activation elements
    kind: str = "dense"


def _conv_macs(hw: int, k: int, cin: int, cout: int, stride: int = 1,
               groups: int = 1) -> ConvLayerSpec:
    out = hw // stride
    macs = out * out * k * k * cin * cout / groups
    return ConvLayerSpec(f"conv{k}x{k}_{cin}-{cout}", macs,
                         k * k * cin * cout / groups, out * out * cout,
                         kind="depthwise" if groups > 1 else "dense")


def mobilenet_v2_layers() -> List[ConvLayerSpec]:
    """224x224 input; inverted residuals (expansion 6)."""
    layers = [_conv_macs(224, 3, 3, 32, 2)]
    spec = [(16, 1, 1, 112), (24, 6, 2, 112), (24, 6, 1, 56), (32, 6, 2, 56),
            (32, 6, 1, 28), (32, 6, 1, 28), (64, 6, 2, 28), (64, 6, 1, 14),
            (64, 6, 1, 14), (64, 6, 1, 14), (96, 6, 1, 14), (96, 6, 1, 14),
            (96, 6, 1, 14), (160, 6, 2, 14), (160, 6, 1, 7), (160, 6, 1, 7),
            (320, 6, 1, 7)]
    cin = 32
    for cout, t, s, hw in spec:
        mid = cin * t
        layers += [_conv_macs(hw, 1, cin, mid),
                   _conv_macs(hw, 3, mid, mid, s, groups=mid),
                   _conv_macs(hw // s, 1, mid, cout)]
        cin = cout
    layers.append(_conv_macs(7, 1, 320, 1280))
    layers.append(ConvLayerSpec("fc", 1280 * 1000, 1280 * 1000, 1000))
    return layers


def resnet50_layers() -> List[ConvLayerSpec]:
    layers = [_conv_macs(224, 7, 3, 64, 2)]
    cfgs = [(64, 256, 3, 56), (128, 512, 4, 28), (256, 1024, 6, 14),
            (512, 2048, 3, 7)]
    cin = 64
    for mid, cout, n, hw in cfgs:
        for i in range(n):
            layers += [_conv_macs(hw, 1, cin, mid),
                       _conv_macs(hw, 3, mid, mid),
                       _conv_macs(hw, 1, mid, cout)]
            cin = cout
    layers.append(ConvLayerSpec("fc", 2048 * 1000, 2048 * 1000, 1000))
    return layers


def inception_v4_layers() -> List[ConvLayerSpec]:
    """Coarse 299x299 Inception-V4 (~12.3 GMACs total, matching published)."""
    layers = [_conv_macs(299, 3, 3, 32, 2), _conv_macs(149, 3, 32, 32),
              _conv_macs(149, 3, 32, 64)]
    for _ in range(4):                                   # Inception-A x4
        layers += [_conv_macs(35, 1, 384, 96), _conv_macs(35, 3, 96, 96),
                   _conv_macs(35, 3, 96, 96), _conv_macs(35, 1, 384, 96)]
    for _ in range(7):                                   # Inception-B x7
        layers += [_conv_macs(17, 1, 1024, 192), _conv_macs(17, 7, 192, 224),
                   _conv_macs(17, 7, 224, 256), _conv_macs(17, 1, 1024, 384)]
    for _ in range(3):                                   # Inception-C x3
        layers += [_conv_macs(8, 1, 1536, 256), _conv_macs(8, 3, 256, 512),
                   _conv_macs(8, 1, 1536, 256)]
    layers.append(ConvLayerSpec("fc", 1536 * 1000, 1536 * 1000, 1000))
    return layers


def ursonet_table1_layers() -> List[ConvLayerSpec]:
    """Full-size UrsoNet as benchmarked in Table I: ResNet-50 backbone on
    the 1280x960 input resampled to ~384x512 (the UrsoNet paper's bottleneck
    resolution), plus the pose FC heads.  Spatial MACs/activations scale
    by (384*512)/(224*224) vs the ImageNet table."""
    scale = (384 * 512) / (224 * 224)
    layers = []
    for l in resnet50_layers()[:-1]:                     # conv backbone
        layers.append(ConvLayerSpec(l.name, l.macs * scale, l.params,
                                    l.activations * scale, l.kind))
    layers.append(ConvLayerSpec("fc_pose", 2048 * 512, 2048 * 512, 512))
    layers.append(ConvLayerSpec("heads", 512 * 7, 512 * 7, 7))
    return layers


def ursonet_layers(cfg: UrsoNetConfig = UrsoNetConfig()) -> List[ConvLayerSpec]:
    h, w = cfg.image_hw
    layers = [_conv_macs(max(h, w), 7, 3, cfg.widths[0], 2)]
    hw = max(h, w) // 2
    cin = cfg.widths[0]
    for si, w_ in enumerate(cfg.widths):
        for b in range(cfg.blocks_per_stage):
            stride = 2 if b == 0 and si > 0 else 1
            layers += [_conv_macs(hw, 3, cin, w_, stride),
                       _conv_macs(hw // stride, 3, w_, w_)]
            hw //= stride
            cin = w_
    layers.append(ConvLayerSpec("fc", cin * cfg.fc_dim, cin * cfg.fc_dim,
                                cfg.fc_dim))
    layers.append(ConvLayerSpec("heads", cfg.fc_dim * 7, cfg.fc_dim * 7, 7))
    return layers
