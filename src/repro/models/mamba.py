"""Mamba-1 selective SSM mixer (Jamba's majority layer).

The selective-scan recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t        (per channel, N states)
    y_t = C_t . h_t + D * x_t

is evaluated with a chunked scan: an outer ``lax.scan`` over sequence
chunks carries h [B, d_inner, N]; inside a chunk an associative scan
materializes [B, Q, d_inner, N] only for Q positions at a time.  This is
the TPU-native replacement for the CUDA selective-scan kernel: VMEM-sized
chunks instead of warp-level fusion (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import pdot
from repro.models.layers import dense_init

CHUNK = 64


def mamba_init(key, cfg: ModelConfig) -> Dict:
    mc = cfg.mamba or MambaConfig()
    d, di = cfg.d_model, (cfg.mamba or MambaConfig()).expand * cfg.d_model
    n, r = mc.d_state, mc.resolved_dt_rank(d)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (mc.d_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, r + 2 * n),
        "dt_proj": dense_init(ks[3], r, di),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d),
    }


class MambaCache(NamedTuple):
    h: jnp.ndarray          # [B, d_inner, N]
    conv: jnp.ndarray       # [B, d_conv-1, d_inner] — trailing inputs


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaCache:
    mc = cfg.mamba or MambaConfig()
    di = mc.expand * cfg.d_model
    return MambaCache(jnp.zeros((batch, di, mc.d_state), dtype),
                      jnp.zeros((batch, mc.d_conv - 1, di), dtype))


def _ssm_params(params, cfg: ModelConfig, xc: jnp.ndarray, policy):
    """xc: [B, L, di] (post-conv) -> dt, B_t, C_t  (fp32, selective)."""
    mc = cfg.mamba or MambaConfig()
    r = mc.resolved_dt_rank(cfg.d_model)
    proj = pdot(xc, params["x_proj"], policy).astype(jnp.float32)
    dt_r, b_t, c_t = jnp.split(proj, [r, r + mc.d_state], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    return dt, b_t, c_t                       # [B,L,di], [B,L,N], [B,L,N]


def _chunk_scan(h0, a_bar, bx):
    """Associative scan within a chunk.  a_bar, bx: [B, Q, di, N]."""
    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])
    a_cum, b_cum = jax.lax.associative_scan(comb, (a_bar, bx), axis=1)
    h = a_cum * h0[:, None] + b_cum           # [B, Q, di, N]
    return h, h[:, -1]


def mamba_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                policy: PrecisionPolicy = DEFAULT_POLICY,
                chunk: int = 0, return_state: bool = False):
    """Full-sequence mixer.  x: [B, S, D] -> [B, S, D] (opt. + cache)."""
    mc = cfg.mamba or MambaConfig()
    chunk = chunk or cfg.scan_chunk or CHUNK
    b, s, d = x.shape
    di = mc.expand * d
    xz = pdot(x, params["in_proj"], policy)
    xr, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over seq
    xp = jnp.pad(xr, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s] * params["conv_w"][i] for i in range(mc.d_conv))
    xc = jax.nn.silu(xc + params["conv_b"]).astype(x.dtype)

    dt, b_t, c_t = _ssm_params(params, cfg, xc, policy)
    a = -jnp.exp(params["A_log"])                       # [di, N]
    xcf = xc.astype(jnp.float32)

    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
        xcf = jnp.pad(xcf, ((0, 0), (0, pad), (0, 0)))

    def outer(h, inp):
        dtq, bq, cq, xq = inp                            # [B, Q, ...]
        a_bar = jnp.exp(dtq[..., None] * a)              # [B,Q,di,N]
        bx = (dtq * xq)[..., None] * bq[:, :, None, :]   # [B,Q,di,N]
        hs, h_last = _chunk_scan(h, a_bar, bx)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cq)
        return h_last, y

    split = lambda t: t.reshape(b, nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, di, mc.d_state), jnp.float32)
    h_fin, ys = jax.lax.scan(outer, h0,
                             (split(dt), split(b_t), split(c_t), split(xcf)),
                             unroll=not cfg.scan_layers)
    y = ys.swapaxes(0, 1).reshape(b, nchunk * chunk, di)[:, :s]
    y = (y + xcf[:, :s] * params["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = pdot(y, params["out_proj"], policy)
    if return_state:
        conv_tail = xr[:, -(mc.d_conv - 1):].astype(jnp.float32)
        return out, MambaCache(h_fin, conv_tail)
    return out


def mamba_decode_step(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      cache: MambaCache,
                      policy: PrecisionPolicy = DEFAULT_POLICY
                      ) -> Tuple[jnp.ndarray, MambaCache]:
    """One-token step.  x: [B, 1, D]."""
    mc = cfg.mamba or MambaConfig()
    xz = pdot(x[:, 0], params["in_proj"], policy)        # [B, 2*di]
    xr, z = jnp.split(xz, 2, axis=-1)

    hist = jnp.concatenate([cache.conv, xr[:, None].astype(cache.conv.dtype)], axis=1)
    xc = jnp.einsum("bkd,kd->bd", hist, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc).astype(x.dtype)

    dt, b_t, c_t = _ssm_params(params, cfg, xc[:, None], policy)
    dt, b_t, c_t = dt[:, 0], b_t[:, 0], c_t[:, 0]
    a = -jnp.exp(params["A_log"])
    a_bar = jnp.exp(dt[..., None] * a)                   # [B,di,N]
    h = a_bar * cache.h + (dt * xc.astype(jnp.float32))[..., None] * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + xc.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z))[:, None]
    return pdot(y, params["out_proj"], policy), MambaCache(h, hist[:, 1:])
