"""RWKV-6 (Finch) mixer — attention-free, data-dependent per-channel decay.

Per head (size N): S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                   y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T).

The sequence dimension is processed with a chunked ``lax.scan`` carrying
S [B, Hp, N, N]; within a chunk an associative scan composes the affine
state maps exactly (no exp-ratio tricks, numerically stable).  Decode
carries (S, previous-token activations) — O(1) state, which is why the
long_500k cell runs for this arch.

TP padding: heads pad to a multiple of the TP degree with zero-weight
projections (40 -> 48 at tp=16); padded heads emit exact zeros.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKVConfig
from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import pdot
from repro.models.layers import dense_init

CHUNK = 16
TMIX_STREAMS = ("r", "k", "v", "w", "g")


def _padded_heads(cfg: ModelConfig, tp: int) -> int:
    rc = cfg.rwkv or RWKVConfig()
    h = cfg.d_model // rc.head_size
    tp = max(tp, 1)
    return ((h + tp - 1) // tp) * tp


def rwkv_dims(cfg: ModelConfig, tp: int) -> Tuple[int, int, int]:
    rc = cfg.rwkv or RWKVConfig()
    hp = _padded_heads(cfg, tp)
    return hp, rc.head_size, hp * rc.head_size     # Hp, N, Dp


def _pad_out(w: jnp.ndarray, dp: int) -> jnp.ndarray:
    return jnp.pad(w, ((0, 0), (0, dp - w.shape[1])))


def time_mix_init(key, cfg: ModelConfig, tp: int = 1) -> Dict:
    rc = cfg.rwkv or RWKVConfig()
    d = cfg.d_model
    hp, n, dp = rwkv_dims(cfg, tp)
    ks = jax.random.split(key, 10)
    p = {
        # ddlerp token-shift mixing
        "mu_x": jnp.full((d,), 0.5, jnp.float32),
        "mu": jnp.full((5, d), 0.5, jnp.float32),
        "mix_A": dense_init(ks[0], d, 5 * rc.mix_lora, scale=0.01),
        "mix_B": jax.random.normal(ks[1], (5, rc.mix_lora, d), jnp.float32) * 0.01,
        # projections (padded out-dim)
        "w_r": _pad_out(dense_init(ks[2], d, d), dp),
        "w_k": _pad_out(dense_init(ks[3], d, d), dp),
        "w_v": _pad_out(dense_init(ks[4], d, d), dp),
        "w_g": _pad_out(dense_init(ks[5], d, d), dp),
        "w_o": jnp.pad(dense_init(ks[6], d, d), ((0, dp - d), (0, 0))),
        # data-dependent decay (lora) + bonus
        "w0": jnp.pad(jnp.linspace(-6.0, -1.0, d), (0, dp - d)).astype(jnp.float32),
        "wA": dense_init(ks[7], d, rc.decay_lora, scale=0.01),
        "wB": jax.random.normal(ks[8], (rc.decay_lora, dp), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[9], (dp,), jnp.float32) * 0.1,
        # per-head group norm
        "gn_scale": jnp.ones((dp,), jnp.float32),
        "gn_bias": jnp.zeros((dp,), jnp.float32),
    }
    return p


def channel_mix_init(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "w_kc": dense_init(ks[0], d, f),
        "w_vc": dense_init(ks[1], f, d),
        "w_rc": dense_init(ks[2], d, d),
    }


class RWKVCache(NamedTuple):
    s: jnp.ndarray          # [B, Hp, N, N] wkv state
    x_tmix: jnp.ndarray     # [B, D] previous token (time-mix shift)
    x_cmix: jnp.ndarray     # [B, D] previous token (channel-mix shift)


def init_rwkv_cache(cfg: ModelConfig, batch: int, tp: int = 1,
                    dtype=jnp.float32) -> RWKVCache:
    hp, n, _ = rwkv_dims(cfg, tp)
    return RWKVCache(jnp.zeros((batch, hp, n, n), dtype),
                     jnp.zeros((batch, cfg.d_model), dtype),
                     jnp.zeros((batch, cfg.d_model), dtype))


def _ddlerp(params: Dict, x: jnp.ndarray, x_prev: jnp.ndarray):
    """RWKV-6 data-dependent token-shift.  x, x_prev: [B, S, D] ->
    the five mixed streams [5, B, S, D] plus x_prev for decay lora."""
    xx = x_prev - x
    xxx = x + xx * params["mu_x"]
    lora = jnp.tanh(xxx.astype(jnp.float32) @ params["mix_A"])
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    dyn = jnp.einsum("bsli,lid->lbsd", lora, params["mix_B"])
    mixed = x[None] + xx[None] * (params["mu"][:, None, None, :] + dyn).astype(x.dtype)
    return mixed, xxx


def _wkv_chunk_scan(s0, w, k, v, r, u, chunk: int, unroll: bool = False):
    """Chunked exact scan.  w,k,v,r: [B, T, Hp, N] fp32; s0: [B,Hp,N,N].
    Returns y [B, T, Hp, N] and final state."""
    b, t, hp, n = k.shape
    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w, k, v, r = z(w), z(k), z(v), z(r)
        w = w.at[:, t:].set(1.0)     # identity decay on padding

    split = lambda a: a.reshape(b, nchunk, chunk, hp, n).swapaxes(0, 1)
    wc, kc, vc, rc = split(w), split(k), split(v), split(r)

    def outer(s, inp):
        wq, kq, vq, rq = inp                               # [B, Q, Hp, N]
        a = wq[..., None]                                  # row decay [B,Q,H,N,1]
        bmat = kq[..., None] * vq[..., None, :]            # [B,Q,H,N,N]

        def comb(l, rgt):
            return (l[0] * rgt[0], rgt[0] * l[1] + rgt[1])
        a_cum, b_cum = jax.lax.associative_scan(comb, (a, bmat), axis=1)
        s_incl = a_cum * s[:, None] + b_cum                # [B,Q,H,N,N]
        s_excl = jnp.concatenate([s[:, None], s_incl[:, :-1]], axis=1)
        y = jnp.einsum("bqhn,bqhnm->bqhm", rq, s_excl)
        y = y + jnp.einsum("bqhn,hn,bqhn,bqhm->bqhm", rq, u, kq, vq)
        return s_incl[:, -1], y

    s_fin, ys = jax.lax.scan(outer, s0, (wc, kc, vc, rc), unroll=unroll)
    return ys.swapaxes(0, 1).reshape(b, nchunk * chunk, hp, n)[:, :t], s_fin


def _group_norm(params: Dict, y: jnp.ndarray, n: int, eps: float = 1e-5):
    """Per-head layer norm over the padded channel dim."""
    shp = y.shape
    yh = y.reshape(*shp[:-1], -1, n).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(shp) * params["gn_scale"] + params["gn_bias"])


def time_mix_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                   x_prev: jnp.ndarray, tp: int = 1,
                   policy: PrecisionPolicy = DEFAULT_POLICY,
                   chunk: int = 0):
    """x: [B,S,D]; x_prev: [B,D] (token before this window).
    Returns (out [B,S,D], final state [B,Hp,N,N], last token [B,D])."""
    chunk = chunk or cfg.scan_chunk or CHUNK
    hp, n, dp = rwkv_dims(cfg, tp)
    b, s, d = x.shape
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mixed, _ = _ddlerp(params, x, shifted)
    xr, xk, xv, xw, xg = [mixed[i] for i in range(5)]

    r = pdot(xr, params["w_r"], policy).reshape(b, s, hp, n).astype(jnp.float32)
    k = pdot(xk, params["w_k"], policy).reshape(b, s, hp, n).astype(jnp.float32)
    v = pdot(xv, params["w_v"], policy).reshape(b, s, hp, n).astype(jnp.float32)
    g = jax.nn.silu(pdot(xg, params["w_g"], policy))

    ww = params["w0"] + jnp.tanh(xw.astype(jnp.float32) @ params["wA"]) @ params["wB"]
    w = jnp.exp(-jnp.exp(ww)).reshape(b, s, hp, n)         # decay in (0,1)

    u = params["u"].reshape(hp, n)
    s0 = jnp.zeros((b, hp, n, n), jnp.float32)
    y, s_fin = _wkv_chunk_scan(s0, w, k, v, r, u, chunk,
                               unroll=not cfg.scan_layers)
    y = _group_norm(params, y.reshape(b, s, dp), n).astype(x.dtype) * g
    return pdot(y, params["w_o"], policy), s_fin, x[:, -1]


def time_mix_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    cache_s: jnp.ndarray, x_prev: jnp.ndarray, tp: int = 1,
                    policy: PrecisionPolicy = DEFAULT_POLICY):
    """One token.  x: [B, D].  Exact recurrence, no chunking."""
    hp, n, dp = rwkv_dims(cfg, tp)
    b, d = x.shape
    mixed, _ = _ddlerp(params, x[:, None], x_prev[:, None])
    xr, xk, xv, xw, xg = [mixed[i][:, 0] for i in range(5)]

    r = pdot(xr, params["w_r"], policy).reshape(b, hp, n).astype(jnp.float32)
    k = pdot(xk, params["w_k"], policy).reshape(b, hp, n).astype(jnp.float32)
    v = pdot(xv, params["w_v"], policy).reshape(b, hp, n).astype(jnp.float32)
    g = jax.nn.silu(pdot(xg, params["w_g"], policy))

    ww = params["w0"] + jnp.tanh(xw.astype(jnp.float32) @ params["wA"]) @ params["wB"]
    w = jnp.exp(-jnp.exp(ww)).reshape(b, hp, n)

    u = params["u"].reshape(hp, n)
    kv = k[..., None] * v[..., None, :]                    # [B,Hp,N,N]
    y = jnp.einsum("bhn,bhnm->bhm", r, cache_s + u[None, ..., None] * kv)
    s_new = w[..., None] * cache_s + kv
    y = _group_norm(params, y.reshape(b, dp), n).astype(x.dtype) * g
    return pdot(y, params["w_o"], policy), s_new, x


def channel_mix_apply(params: Dict, x: jnp.ndarray, x_prev: jnp.ndarray,
                      policy: PrecisionPolicy = DEFAULT_POLICY):
    """x: [B,S,D]; x_prev: [B,D].  Returns (out, last token)."""
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = x + (shifted - x) * params["mu_k"]
    xr = x + (shifted - x) * params["mu_r"]
    k = jnp.square(jax.nn.relu(pdot(xk, params["w_kc"], policy)))
    v = pdot(k, params["w_vc"], policy)
    return jax.nn.sigmoid(pdot(xr, params["w_rc"], policy)) * v, x[:, -1]


def channel_mix_decode(params: Dict, x: jnp.ndarray, x_prev: jnp.ndarray,
                       policy: PrecisionPolicy = DEFAULT_POLICY):
    out, _ = channel_mix_apply(params, x[:, None], x_prev, policy)
    return out[:, 0], x
