"""Grouped-query attention with TP-aware head padding.

TP head padding (DESIGN.md §5): head counts that do not divide the tensor-
parallel degree are padded.  We pick the smallest (Hp, KVp) with

    tp | Hp,  tp | KVp,  kv | KVp,  KVp | Hp,  Hp >= H,

replicate each real kv head into its ``KVp // kv`` padded slots and lay the
real q heads of one kv group contiguously across those slots.  Every padded
q slot beyond the real heads carries zero weights, so the math is exact and
each shard's q heads attend only to that shard's kv heads — no cross-shard
gathers.  (kv-cache inflation is KVp/kv, e.g. 2x for qwen3 at tp=16.)

Three execution paths:
  * ``full``    — materialized scores (small seq / smoke tests)
  * ``chunked`` — online-softmax scan over kv chunks (32k prefill; the
                  XLA-native analogue of the Pallas flash kernel)
  * ``decode``  — single-token query against a KV cache
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import pdot
from repro.kernels import ops as kops
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init
from repro.runtime import paging

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Head-padding layout
# ---------------------------------------------------------------------------
class HeadLayout(NamedTuple):
    H: int            # real q heads
    KV: int           # real kv heads
    Hp: int           # padded q heads
    KVp: int          # padded kv heads
    gp: int           # padded group size  Hp // KVp


def head_layout(h: int, kv: int, tp: int) -> HeadLayout:
    tp = max(tp, 1)
    kvp = math.lcm(kv, tp)
    hp = ((max(h, kvp) + kvp - 1) // kvp) * kvp
    assert hp % tp == 0 and kvp % tp == 0 and hp % kvp == 0
    return HeadLayout(h, kv, hp, kvp, hp // kvp)


def q_slot_map(layout: HeadLayout) -> jnp.ndarray:
    """[Hp] -> real q head index, or -1 for a pad slot."""
    H, KV, Hp, _, _ = layout
    g = H // KV                       # real group size
    per_kv = Hp // KV                 # padded q slots per real kv head
    src = -jnp.ones((Hp,), jnp.int32)
    for c in range(KV):
        for r in range(g):
            src = src.at[c * per_kv + r].set(c * g + r)
    return src


def kv_slot_map(layout: HeadLayout) -> jnp.ndarray:
    """[KVp] -> real kv head index (every padded kv slot is a replica)."""
    return jnp.arange(layout.KVp, dtype=jnp.int32) // (layout.KVp // layout.KV)


def _pad_proj(w: jnp.ndarray, slot_map: jnp.ndarray, head_dim: int) -> jnp.ndarray:
    """Expand [D, n_real*hd] -> [D, n_pad*hd] following slot_map (-1 -> 0)."""
    d = w.shape[0]
    wr = w.reshape(d, -1, head_dim)
    safe = jnp.maximum(slot_map, 0)
    out = wr[:, safe, :] * (slot_map >= 0)[None, :, None]
    return out.reshape(d, -1)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig, tp: int = 1) -> Dict:
    hd = cfg.resolved_head_dim()
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, tp)
    kq, kk, kv_, ko = jax.random.split(key, 4)
    wq = _pad_proj(dense_init(kq, cfg.d_model, cfg.num_heads * hd), q_slot_map(lay), hd)
    wk = _pad_proj(dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd), kv_slot_map(lay), hd)
    wv = _pad_proj(dense_init(kv_, cfg.d_model, cfg.num_kv_heads * hd), kv_slot_map(lay), hd)
    wo_r = dense_init(ko, cfg.num_heads * hd, cfg.d_model, scale=1.0 / math.sqrt(cfg.num_heads * hd))
    # rows of wo follow the padded q layout (pad rows zero)
    smap = q_slot_map(lay)
    wo = (wo_r.reshape(-1, hd, cfg.d_model)[jnp.maximum(smap, 0)]
          * (smap >= 0)[:, None, None]).reshape(lay.Hp * hd, cfg.d_model)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


# ---------------------------------------------------------------------------
# Score paths
# ---------------------------------------------------------------------------
def _mask(qpos, kpos, window: int):
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def full_attention(q, k, v, qpos, kpos, window: int = 0) -> jnp.ndarray:
    """q: [B,S,KVp,gp,hd]  k,v: [B,T,KVp,hd] -> [B,S,KVp,gp,hd]."""
    hd = q.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = jnp.where(_mask(qpos, kpos, window)[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q, k, v, qpos, kpos, window: int = 0,
                      chunk: int = 1024, unroll: bool = False) -> jnp.ndarray:
    """Online-softmax scan over kv chunks (flash-style, pure XLA)."""
    b, s, kvp, gp, hd = q.shape
    t = k.shape[1]
    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=2 ** 30)
    kc = k.reshape(b, nchunk, chunk, kvp, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kvp, hd).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(nchunk, chunk)
    qf = q.astype(jnp.float32) / math.sqrt(hd)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s_blk = jnp.einsum("bskgd,btkd->bkgst", qf, kb.astype(jnp.float32))
        s_blk = jnp.where(_mask(qpos, pb, window)[None, None, None], s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((b, kvp, gp, s), NEG_INF, jnp.float32),
            jnp.zeros((b, kvp, gp, s), jnp.float32),
            jnp.zeros((b, kvp, gp, s, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kc, vc, pc), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # [B,S,KVp,gp,hd]


# ---------------------------------------------------------------------------
# Block-level apply
# ---------------------------------------------------------------------------
def _project_qkv(params, cfg: ModelConfig, x, positions,
                 policy: PrecisionPolicy):
    hd = cfg.resolved_head_dim()
    b, s, _ = x.shape
    q = pdot(x, params["wq"], policy).reshape(b, s, -1, hd)
    k = pdot(x, params["wk"], policy).reshape(b, s, -1, hd)
    v = pdot(x, params["wv"], policy).reshape(b, s, -1, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta:          # theta == 0 -> no positional rotation (Jamba)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray,
                    policy: PrecisionPolicy = DEFAULT_POLICY,
                    chunked: Optional[bool] = None) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim()
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    kvp = k.shape[2]
    q = q.reshape(b, s, kvp, -1, hd)
    pos1 = positions[0] if positions.ndim == 2 else positions
    if chunked is None:
        chunked = s > 2048
    if chunked:
        out = chunked_attention(q, k, v, pos1, pos1, cfg.sliding_window,
                                unroll=not cfg.scan_layers)
    else:
        out = full_attention(q, k, v, pos1, pos1, cfg.sliding_window)
    out = out.reshape(b, s, -1)
    return pdot(out, params["wo"], policy)


class KVCache(NamedTuple):
    k: jnp.ndarray        # [B, T, KVp, hd]  (bf16, or int8 + scales)
    v: jnp.ndarray
    pos: jnp.ndarray      # [] int32 — next write slot (== tokens seen)
    k_scale: Optional[jnp.ndarray] = None   # [B, T, KVp, 1] f32 (int8 mode)
    v_scale: Optional[jnp.ndarray] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def _cache_quant(x: jnp.ndarray):
    """Per-token-per-head absmax int8.  x: [..., hd]."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _cache_deq(q: jnp.ndarray, scale) -> jnp.ndarray:
    if scale is None:
        return q.astype(jnp.float32)
    return q.astype(jnp.float32) * scale


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1,
                  dtype=jnp.bfloat16) -> KVCache:
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, tp)
    hd = cfg.resolved_head_dim()
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, length, lay.KVp, hd)
    if cfg.kv_cache_dtype == "int8":
        sshape = (batch, length, lay.KVp, 1)
        return KVCache(jnp.zeros(shape, jnp.int8),
                       jnp.zeros(shape, jnp.int8),
                       jnp.zeros((), jnp.int32),
                       jnp.ones(sshape, jnp.float32),
                       jnp.ones(sshape, jnp.float32))
    return KVCache(jnp.zeros(shape, dtype),
                   jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def paged_decode_attention_apply(params: Dict, cfg: ModelConfig,
                                 x: jnp.ndarray,
                                 state: paging.PagedKVState,
                                 positions: jnp.ndarray,
                                 policy: PrecisionPolicy = DEFAULT_POLICY
                                 ) -> Tuple[jnp.ndarray, paging.PagedKVState]:
    """One-token decode step against a paged KV cache.  x: [B, 1, D].

    Unlike the dense path there is no single ``cache.pos``: every batch
    slot sits at its own context length, so the caller passes per-
    sequence rope ``positions`` [B, 1] (== ``state.lengths[:, None]``).
    The new token is appended into the slot's current block and
    attention walks the block table in the Pallas kernel — the full KV
    is never materialized.  Sliding-window and int8 KV modes are dense-
    path-only (the serving engine rejects those configs up front).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim()
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    state = paging.append_tokens(state, k[:, 0], v[:, 0])
    kvp = k.shape[2]
    qh = q[:, 0].reshape(b, kvp, -1, hd)          # grouped-query layout
    out = kops.paged_attention(qh, state.k_pool, state.v_pool,
                               state.block_table, state.lengths)
    out = out.reshape(b, 1, -1).astype(x.dtype)
    return pdot(out, params["wo"], policy), state


def paged_chunk_attention_apply(params: Dict, cfg: ModelConfig,
                                x: jnp.ndarray,
                                state: paging.PagedKVState,
                                positions: jnp.ndarray, seq,
                                policy: PrecisionPolicy = DEFAULT_POLICY
                                ) -> Tuple[jnp.ndarray, paging.PagedKVState]:
    """One prefill *chunk* of sequence ``seq`` against the paged cache.

    x: [1, C, D]; positions: [1, C] absolute (chunk start must be
    block-aligned, C a whole number of blocks).  The chunk's KV is
    pasted into the sequence's blocks first (``write_prefill_chunk``),
    then attention reads the pool back through the block-table walk —
    queries see the paged prefix written by earlier chunks plus the
    in-chunk causal triangle, so chunked prefill computes exactly the
    full-prompt attention, C tokens at a time.  ``seq`` may be traced.
    """
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim()
    q, k, v = _project_qkv(params, cfg, x, positions, policy)
    state = paging.write_prefill_chunk(state, k[0], v[0], seq,
                                       positions[0, 0])
    kvp = k.shape[2]
    qh = q[0].reshape(c, kvp, -1, hd)             # grouped-query layout
    out = kops.paged_chunk_attention(qh, state.k_pool, state.v_pool,
                                     state.block_table[seq], positions[0])
    out = out.reshape(b, c, -1).astype(x.dtype)
    return pdot(out, params["wo"], policy), state


def decode_attention_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                           cache: KVCache,
                           policy: PrecisionPolicy = DEFAULT_POLICY
                           ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode step.  x: [B, 1, D]."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim()
    t = cache.k.shape[1]
    pos = jnp.full((b, 1), cache.pos, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, pos, policy)
    slot = (cache.pos % t) if cfg.sliding_window else jnp.minimum(cache.pos, t - 1)
    upd = lambda buf, val: jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0, slot, 0, 0))
    if cache.quantized:
        kq, ks = _cache_quant(k)
        vq, vs = _cache_quant(v)
        new_cache = KVCache(upd(cache.k, kq), upd(cache.v, vq),
                            cache.pos + 1,
                            upd(cache.k_scale, ks), upd(cache.v_scale, vs))
    else:
        new_cache = KVCache(upd(cache.k, k), upd(cache.v, v), cache.pos + 1)
    kvp = k.shape[2]
    qh = q.reshape(b, 1, kvp, -1, hd).astype(jnp.float32)
    k_read = _cache_deq(new_cache.k, new_cache.k_scale)
    v_read = _cache_deq(new_cache.v, new_cache.v_scale)
    scores = jnp.einsum("bskgd,btkd->bkgst", qh / math.sqrt(hd), k_read)
    # valid slots: written and (if windowed) within the ring buffer
    idx = jnp.arange(t)
    valid = idx < jnp.minimum(cache.pos + 1, t)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v_read)
    out = out.astype(x.dtype).reshape(b, 1, -1)
    return pdot(out, params["wo"], policy), new_cache
