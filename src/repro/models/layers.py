"""Shared model building blocks (pure JAX, init/apply style).

Every matmul routes through :func:`repro.core.quantization.pdot` so the
MPAI precision policy of the enclosing segment applies uniformly.  Norms,
routers and rotary phases always run in fp32 — they are tiny and
accuracy-critical (the same argument the paper makes for keeping the FC
head on the FP16 VPU).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import pdot


def dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


# ---------------------------------------------------------------------------
# Norms (always fp32 compute)
# ---------------------------------------------------------------------------
def rmsnorm_init(dim: int) -> Dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(dim: int) -> Dict:
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


def make_norm(norm_type: str):
    if norm_type == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if norm_type == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(norm_type)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] int32."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                          # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, glu: bool) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff),
         "w_out": dense_init(ks[1], d_ff, d_model)}
    if glu:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_apply(params: Dict, x: jnp.ndarray, act: str = "silu", glu: bool = True,
              policy: PrecisionPolicy = DEFAULT_POLICY) -> jnp.ndarray:
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = pdot(x, params["w_in"], policy)
    if glu:
        h = a(pdot(x, params["w_gate"], policy)) * h
    else:
        h = a(h)
    return pdot(h, params["w_out"], policy)


# ---------------------------------------------------------------------------
# Embedding / LM head (vocab-sharded over the model axis)
# ---------------------------------------------------------------------------
def embedding_init(key, vocab: int, d_model: int) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def embed(table: jnp.ndarray, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return table.astype(dtype)[tokens]


def lm_logits(table: jnp.ndarray, x: jnp.ndarray,
              policy: PrecisionPolicy = DEFAULT_POLICY) -> jnp.ndarray:
    """Logits against the (possibly tied) embedding table [V, D]."""
    dt = policy.precision.compute_dtype
    return jnp.matmul(x.astype(dt), table.astype(dt).T)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy, fp32-stable, vocab-sharding-safe.

    The gold logit comes from a masked reduction over the vocab axis
    instead of ``take_along_axis`` — a gather over a vocab-sharded logits
    tensor forces the SPMD partitioner to materialize/gather the full
    [tokens, V] array, while select+reduce partitions cleanly (local
    partial + small psum).  §Perf iteration 1.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jnp.arange(vocab, dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)
