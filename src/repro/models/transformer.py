"""Model composition: sublayer pattern -> super-blocks -> segment scans.

Layer stacks are built from *super-blocks*: the smallest periodic unit of
the architecture's layer pattern (1 for homogeneous archs, 8 for Jamba's
1-attention-per-8 + MoE-every-2 interleave).  Super-blocks are stacked
with a leading dimension and executed with ``lax.scan`` — essential to
keep HLO size sane at 126 layers x 512 devices.

MPAI integration: a :class:`~repro.core.partition.PartitionPlan` splits
the stack into contiguous segments; each segment runs its own scan under
its own precision policy, so a partition boundary is literally a scan
boundary (and, in stage-pipeline mode, a device-group boundary).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.core.precision import PrecisionPolicy
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.runtime import paging
from repro.runtime.paging import PagedKVState
from repro.models import rwkv as rwk
from repro.models import moe as moe_mod
from repro.models.layers import (cross_entropy, embed, embedding_init,
                                 lm_logits, make_norm, mlp_apply, mlp_init)

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------
def pattern_period(cfg: ModelConfig) -> int:
    p = cfg.attn_every if cfg.mixer == "hybrid" else 1
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p


def sublayer_pattern(cfg: ModelConfig) -> Tuple[Tuple[str, bool], ...]:
    p = pattern_period(cfg)
    pat = tuple((cfg.layer_mixer(j), cfg.is_moe_layer(j)) for j in range(p))
    # the pattern must be offset-invariant (periodicity check)
    for i in range(cfg.num_layers):
        assert (cfg.layer_mixer(i), cfg.is_moe_layer(i)) == pat[i % p], cfg.name
    return pat


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _sublayer_init(key, cfg: ModelConfig, kind: str, is_moe: bool, tp: int) -> Dict:
    norm_init, _ = make_norm("rmsnorm")
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.d_model),
                         "norm2": norm_init(cfg.d_model)}
    if kind == "attention":
        p["mixer"] = attn.attention_init(k1, cfg, tp)
    elif kind == "mamba":
        p["mixer"] = mam.mamba_init(k1, cfg)
    elif kind == "rwkv6":
        p["mixer"] = rwk.time_mix_init(k1, cfg, tp)
    else:
        raise ValueError(kind)
    if kind == "rwkv6":
        p["mlp"] = rwk.channel_mix_init(k2, cfg)
    elif is_moe:
        p["mlp"] = moe_mod.moe_init(k2, cfg.d_model, cfg.moe, cfg.glu)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu)
    return p


def superblock_init(key, cfg: ModelConfig, tp: int) -> Dict:
    pat = sublayer_pattern(cfg)
    keys = jax.random.split(key, len(pat))
    return {f"sub_{j}": _sublayer_init(keys[j], cfg, kind, is_moe, tp)
            for j, (kind, is_moe) in enumerate(pat)}


def model_init(key, cfg: ModelConfig, tp: int = 1) -> Dict:
    period = pattern_period(cfg)
    n_super = cfg.num_layers // period
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, n_super)
    blocks = [superblock_init(layer_keys[i], cfg, tp) for i in range(n_super)]
    layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    norm_init, _ = make_norm("rmsnorm")
    params = {"embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model),
              "layers": layers,
              "final_norm": norm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(k_head, cfg.vocab_size, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Per-sublayer caches (decode)
# ---------------------------------------------------------------------------
def _sublayer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    tp: int):
    if kind == "attention":
        return attn.init_kv_cache(cfg, batch, max_len, tp)
    if kind == "mamba":
        return mam.init_mamba_cache(cfg, batch)
    if kind == "rwkv6":
        return rwk.init_rwkv_cache(cfg, batch, tp)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1):
    pat = sublayer_pattern(cfg)
    n_super = cfg.num_layers // len(pat)
    one = {f"sub_{j}": _sublayer_cache(cfg, kind, batch, max_len, tp)
           for j, (kind, _) in enumerate(pat)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_super,) + x.shape), one)


def init_paged_decode_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                            block_size: int, tp: int = 1,
                            max_blocks: Optional[int] = None,
                            dtype=jnp.bfloat16):
    """Per-sublayer paged KV caches for continuous-batching decode.

    Every attention sublayer gets its own block pool; table and lengths
    are replicated per super-block so the stacked cache scans exactly
    like the dense one (the engine keeps them in lockstep from its
    host-side mirror).  Paged decode is attention-only for now — SSM /
    RWKV recurrent states are tiny and per-slot already, but their
    prefill handoff into a mixed paged batch is future work (see
    ROADMAP "decode engine").
    """
    pat = sublayer_pattern(cfg)
    bad = [kind for kind, _ in pat if kind != "attention"]
    if bad:
        raise ValueError(f"paged decode needs an attention-only stack; "
                         f"{cfg.name} has {sorted(set(bad))} sublayers")
    if cfg.sliding_window:
        raise ValueError("paged decode does not support sliding windows")
    if cfg.kv_cache_dtype == "int8":
        raise ValueError("paged decode does not support int8 KV caches")
    lay = attn.head_layout(cfg.num_heads, cfg.num_kv_heads, tp)
    hd = cfg.resolved_head_dim()
    n_super = cfg.num_layers // len(pat)
    one = {f"sub_{j}": paging.init_paged_cache(
               batch, num_blocks, block_size, lay.KVp, hd, dtype=dtype,
               max_blocks=max_blocks)
           for j in range(len(pat))}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_super,) + x.shape), one)


def first_paged_state(cache) -> Optional[PagedKVState]:
    """The first PagedKVState in a cache tree (None when fully dense)."""
    for leaf in jax.tree_util.tree_leaves(
            cache, is_leaf=lambda s: isinstance(s, PagedKVState)):
        if isinstance(leaf, PagedKVState):
            return leaf
    return None


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------
def _sublayer_apply(sub: Dict, cfg: ModelConfig, kind: str, is_moe: bool,
                    x: jnp.ndarray, positions: jnp.ndarray,
                    policy: PrecisionPolicy, tp: int,
                    cache=None, decode: bool = False, chunk_seq=None):
    """Returns (x, new_cache, aux).  ``chunk_seq`` (paged caches only)
    switches prefill into chunked-paged mode: the window is one chunk of
    sequence ``chunk_seq`` at absolute ``positions``, pasted into its
    blocks and attended against the paged prefix."""
    _, norm = make_norm("rmsnorm")
    aux = jnp.zeros((), jnp.float32)
    h = norm(sub["norm1"], x, cfg.norm_eps)
    new_cache = cache
    if kind == "attention":
        if decode:
            if isinstance(cache, PagedKVState):
                out, new_cache = attn.paged_decode_attention_apply(
                    sub["mixer"], cfg, h, cache, positions, policy)
            else:
                out, new_cache = attn.decode_attention_apply(
                    sub["mixer"], cfg, h, cache, policy)
        elif chunk_seq is not None and isinstance(cache, PagedKVState):
            out, new_cache = attn.paged_chunk_attention_apply(
                sub["mixer"], cfg, h, cache, positions, chunk_seq, policy)
        else:
            out = attn.attention_apply(sub["mixer"], cfg, h, positions, policy)
            if cache is not None:
                new_cache = _prefill_kv(sub["mixer"], cfg, h, positions, cache,
                                        policy)
    elif kind == "mamba":
        if decode:
            out, new_cache = mam.mamba_decode_step(sub["mixer"], cfg, h, cache,
                                                   policy)
        else:
            if cache is not None:
                out, new_cache = mam.mamba_apply(sub["mixer"], cfg, h, policy,
                                                 return_state=True)
            else:
                out = mam.mamba_apply(sub["mixer"], cfg, h, policy)
    elif kind == "rwkv6":
        if decode:
            y, s_new, x_last = rwk.time_mix_decode(sub["mixer"], cfg, h[:, 0],
                                                   cache.s, cache.x_tmix, tp,
                                                   policy)
            out = y[:, None] if y.ndim == 2 else y
        else:
            out, s_new, x_last = rwk.time_mix_apply(
                sub["mixer"], cfg, h,
                jnp.zeros_like(h[:, 0]) if cache is None else cache.x_tmix,
                tp, policy)
        if cache is not None:
            new_cache = rwk.RWKVCache(s_new, x_last, cache.x_cmix)
    else:
        raise ValueError(kind)
    x = x + out

    h2 = norm(sub["norm2"], x, cfg.norm_eps)
    if kind == "rwkv6":
        if decode:
            out2, x_last2 = rwk.channel_mix_decode(sub["mlp"], h2[:, 0],
                                                   new_cache.x_cmix, policy)
            out2 = out2[:, None]
        else:
            prev = (jnp.zeros_like(h2[:, 0]) if new_cache is None
                    else new_cache.x_cmix)
            out2, x_last2 = rwk.channel_mix_apply(sub["mlp"], h2, prev, policy)
        if new_cache is not None:
            new_cache = rwk.RWKVCache(new_cache.s, new_cache.x_tmix, x_last2)
    elif is_moe:
        out2, aux = moe_mod.moe_apply(sub["mlp"], cfg.moe, h2, cfg.act,
                                      cfg.glu, policy)
    else:
        out2 = mlp_apply(sub["mlp"], h2, cfg.act, cfg.glu, policy)
    return x + out2, new_cache, aux


def _prefill_kv(mix_params, cfg, h, positions, cache, policy):
    """Recompute k/v over the prefill window and write into the cache."""
    q, k, v = attn._project_qkv(mix_params, cfg, h, positions, policy)
    s = k.shape[1]
    t = cache.k.shape[1]
    ks = vs = None
    if cache.quantized:
        k, ks = attn._cache_quant(k)
        v, vs = attn._cache_quant(v)

    def write(buf, val):
        if val is None:
            return None
        if s >= t:        # keep only the trailing window, ring-aligned so
            # that position p lands in slot p % t (decode's ring writes)
            shift = (s - t) % t
            return jnp.roll(val[:, s - t:], shift, axis=1).astype(buf.dtype)
        return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                            (0, 0, 0, 0))
    if cache.quantized:
        return attn.KVCache(write(cache.k, k), write(cache.v, v),
                            cache.pos + s,
                            write(cache.k_scale, ks),
                            write(cache.v_scale, vs))
    return attn.KVCache(write(cache.k, k), write(cache.v, v), cache.pos + s)


# ---------------------------------------------------------------------------
# Segment scans
# ---------------------------------------------------------------------------
def _segment_scan(seg_params, cfg: ModelConfig, x, positions,
                  policy: PrecisionPolicy, tp: int, caches=None,
                  decode: bool = False, chunk_seq=None):
    """Scan a segment's super-blocks.  Returns (x, new_caches, aux_sum)."""
    pat = sublayer_pattern(cfg)

    def block(x, blk_params, blk_cache):
        aux_tot = jnp.zeros((), jnp.float32)
        new_blk_cache = {} if blk_cache is not None else None
        for j, (kind, is_moe) in enumerate(pat):
            c = None if blk_cache is None else blk_cache[f"sub_{j}"]
            x, c2, aux = _sublayer_apply(blk_params[f"sub_{j}"], cfg, kind,
                                         is_moe, x, positions, policy, tp,
                                         c, decode, chunk_seq)
            if new_blk_cache is not None:
                new_blk_cache[f"sub_{j}"] = c2
            aux_tot = aux_tot + aux
        return x, new_blk_cache, aux_tot

    if cfg.remat:
        ckpt_policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None)
        block = jax.checkpoint(block, static_argnums=(), policy=ckpt_policy)
        if cfg.remat_group and not cfg.scan_layers:
            # probes mimic the sqrt-remat double-recompute cost structure
            block = jax.checkpoint(block, static_argnums=())

    if not cfg.scan_layers:          # unrolled (cost probes, tiny models)
        n = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(n):
            blk_params = jax.tree_util.tree_map(lambda a: a[i], seg_params)
            blk_cache = (None if caches is None else
                         jax.tree_util.tree_map(lambda a: a[i], caches))
            x, nc, a = block(x, blk_params, blk_cache)
            aux = aux + a
            new_caches.append(nc)
        if caches is None:
            return x, None, aux
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_caches)
        return x, stacked, aux

    if caches is None:
        def body(carry, blk_params):
            x, aux = carry
            x, _, a = block(x, blk_params, None)
            return (x, aux + a), None

        g = cfg.remat_group
        n = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
        if cfg.remat and g and n % max(g, 1) == 0 and n >= g > 0:
            # sqrt remat: outer scan over groups of g blocks, each group
            # checkpointed as a unit (saves n/g + g boundaries, not n)
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape(n // g, g, *a.shape[1:]), seg_params)

            @jax.checkpoint
            def group_body(carry, grp_params):
                return jax.lax.scan(body, carry, grp_params)[0], None
            (x, aux), _ = jax.lax.scan(
                group_body, (x, jnp.zeros((), jnp.float32)), grouped)
            return x, None, aux
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   seg_params)
        return x, None, aux

    def body(carry, inp):
        blk_params, blk_cache = inp
        x, aux = carry
        x, new_cache, a = block(x, blk_params, blk_cache)
        return (x, aux + a), new_cache
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (seg_params, caches))
    return x, new_caches, aux


def _slice_stack(tree, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------
class LMOutput(NamedTuple):
    logits: jnp.ndarray
    cache: Any
    aux_loss: jnp.ndarray


def forward(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
            plan: Optional[PartitionPlan] = None, tp: int = 1,
            cache=None, decode: bool = False,
            frontend_embeds: Optional[jnp.ndarray] = None,
            chunk=None) -> LMOutput:
    """Unified forward.

    * train/prefill: tokens [B, S], cache None or prefill-target cache
    * decode: tokens [B, 1], cache required
    * chunked paged prefill: tokens [1, C], cache paged, ``chunk`` =
      ``(seq, start)`` — one chunk of sequence ``seq`` at absolute
      positions ``start .. start+C-1`` (see :func:`prefill_paged_chunk`)
    """
    period = pattern_period(cfg)
    plan = plan or PartitionPlan.uniform(cfg.num_layers)
    plan = plan.align_to_period(period, cfg.num_layers)
    plan.validate(cfg.num_layers, period)

    x = embed(params["embed"], tokens,
              plan.embed_policy.precision.compute_dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    chunk_seq = None
    if decode:
        ps = first_paged_state(cache)
        if ps is not None:
            # paged decode: every batch slot sits at its own context
            # length, so positions are per-sequence, not a scalar
            lens = ps.lengths[0] if ps.lengths.ndim == 2 else ps.lengths
            positions = lens[:, None].astype(jnp.int32)
        else:
            start = cache_position(cfg, cache)
            positions = jnp.broadcast_to(start,
                                         (x.shape[0], 1)).astype(jnp.int32)
    elif chunk is not None:
        chunk_seq, start = chunk
        positions = (jnp.asarray(start, jnp.int32)
                     + jnp.arange(x.shape[1], dtype=jnp.int32))[None, :]
    else:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                     x.shape[:2])

    aux_total = jnp.zeros((), jnp.float32)
    new_cache_parts = []
    for seg in plan.segments:
        lo, hi = seg.start // period, seg.end // period
        seg_params = _slice_stack(params["layers"], lo, hi)
        seg_cache = None if cache is None else _slice_stack(cache, lo, hi)
        x, seg_new, aux = _segment_scan(seg_params, cfg, x, positions,
                                        seg.policy, tp, seg_cache, decode,
                                        chunk_seq)
        new_cache_parts.append(seg_new)
        aux_total = aux_total + aux

    _, norm = make_norm("rmsnorm")
    x = norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = lm_logits(table, x, plan.head_policy)

    new_cache = None
    if cache is not None:
        new_cache = jax.tree_util.tree_map(
            lambda *parts: jnp.concatenate(parts, axis=0), *new_cache_parts)
    return LMOutput(logits, new_cache, aux_total)


def cache_position(cfg: ModelConfig, cache) -> jnp.ndarray:
    """Current decode position from the first attention cache (or 0-d int)."""
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda x: x, cache))
    # attention caches carry pos as an int32 scalar per layer stack
    for leaf in leaves:
        if leaf.dtype == jnp.int32 and leaf.ndim == 1:
            return leaf[0]
    return jnp.zeros((), jnp.int32)


def loss_fn(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, plan: Optional[PartitionPlan] = None,
            tp: int = 1,
            frontend_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    out = forward(params, cfg, tokens, plan, tp,
                  frontend_embeds=frontend_embeds)
    logits = out.logits[:, -tokens.shape[1]:]   # skip frontend positions
    return cross_entropy(logits, labels) + AUX_LOSS_COEF * out.aux_loss


def decode_step(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray, cache,
                plan: Optional[PartitionPlan] = None, tp: int = 1) -> LMOutput:
    return forward(params, cfg, tokens, plan, tp, cache=cache, decode=True)


def prefill(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray, cache,
            plan: Optional[PartitionPlan] = None, tp: int = 1,
            frontend_embeds: Optional[jnp.ndarray] = None) -> LMOutput:
    return forward(params, cfg, tokens, plan, tp, cache=cache, decode=False,
                   frontend_embeds=frontend_embeds)


def prefill_paged_chunk(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                        caches, seq, start,
                        plan: Optional[PartitionPlan] = None,
                        tp: int = 1) -> LMOutput:
    """One chunk of a chunked paged prefill.

    tokens: [1, C] — tokens ``start .. start+C-1`` of sequence ``seq``
    (``start`` and ``C`` block-aligned); ``caches`` is the engine's
    paged cache tree.  The chunk's KV lands directly in the sequence's
    blocks and attention reads the paged prefix back, so no dense
    scratch cache bounds the prompt length.  ``seq``/``start`` may be
    traced — the serving engine jits this once per chunk shape.
    Returns logits for the chunk (callers use ``logits[:, -1]`` of the
    final chunk to sample the first output token) and the updated caches.
    """
    return forward(params, cfg, tokens, plan, tp, cache=caches,
                   decode=False, chunk=(seq, start))
