"""Modality frontends — STUBS per the assignment.

``[vlm]`` / ``[audio]`` entries specify the transformer backbone only; the
modality frontend delivers *precomputed* frame/patch embeddings.  These
helpers produce (a) ShapeDtypeStructs for dry-runs and (b) deterministic
synthetic embeddings for smoke tests.

llava-next: anyres tiling yields a variable number of patch embeddings; we
fix it at ``frontend_tokens`` (the base 576-patch grid for smoke configs).
musicgen: EnCodec frames arrive as embeddings over the 2048-entry codebook
vocabulary; token interleaving across codebooks is upstream of the backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    return (batch, cfg.frontend_tokens, cfg.d_model)


def frontend_embeds_spec(cfg: ModelConfig, batch: int,
                         dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(frontend_embed_shape(cfg, batch), dtype)


def synthetic_frontend_embeds(cfg: ModelConfig, batch: int, seed: int = 0,
                              dtype=jnp.bfloat16) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, frontend_embed_shape(cfg, batch),
                              jnp.float32) * 0.02).astype(dtype)
