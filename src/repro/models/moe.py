"""Token-choice top-k Mixture-of-Experts with capacity-bounded scatter
dispatch.

The dispatch avoids the classic [tokens, experts, capacity] one-hot einsum
(O(T*E*C) memory — hopeless at 1M tokens) in favour of a scatter/gather
formulation: slot indices come from a cumulative-sum over the flattened
top-k assignments, token embeddings are scattered into a dense
[E, C, D] buffer (XLA turns this into an all-to-all under expert sharding),
experts run as one batched einsum, and outputs gather back with their gate
weights.  Overflowing tokens are dropped (capacity_factor bounds the drop
rate), matching Switch/GShard semantics.

Routers always run in fp32 and are never quantized — they are the MoE
analogue of the accuracy-critical FC head the paper pins to the FP16 VPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.precision import PrecisionPolicy, DEFAULT_POLICY
from repro.core.quantization import pdot, fake_quant

from repro.models.layers import dense_init


def moe_init(key, d_model: int, moe: MoEConfig, glu: bool) -> Dict:
    ks = jax.random.split(key, 5)
    e, f = moe.num_experts, moe.d_ff_expert
    p = {
        "router": dense_init(ks[0], d_model, e, scale=0.02),
        "w_in": jax.random.normal(ks[1], (e, d_model, f), jnp.float32) / jnp.sqrt(d_model),
        "w_out": jax.random.normal(ks[2], (e, f, d_model), jnp.float32) / jnp.sqrt(f),
    }
    if glu:
        p["w_gate"] = jax.random.normal(ks[3], (e, d_model, f), jnp.float32) / jnp.sqrt(d_model)
    if moe.shared_d_ff:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, moe.shared_d_ff, glu)
    return p


def _expert_ffn(params: Dict, buf: jnp.ndarray, act: str, glu: bool,
                policy: PrecisionPolicy) -> jnp.ndarray:
    """buf: [E, C, D] or [G, E, C, D] -> same, through each expert's MLP."""
    from repro.core.quantization import QTensor
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    w_in, w_out = params["w_in"], params["w_out"]
    w_gate = params.get("w_gate")
    dq = lambda w: (w.dequantize(policy.precision.compute_dtype)
                    if isinstance(w, QTensor) else w)
    w_in, w_out = dq(w_in), dq(w_out)
    w_gate = dq(w_gate) if w_gate is not None else None
    if policy.mode == "fake":
        w_in = fake_quant(w_in, channel_axis=-1)
        w_out = fake_quant(w_out, channel_axis=-1)
        w_gate = fake_quant(w_gate, channel_axis=-1) if w_gate is not None else None
        buf = fake_quant(buf)
    dt = policy.precision.compute_dtype
    bufc = buf.astype(dt)
    g = "g" if buf.ndim == 4 else ""
    h = jnp.einsum(f"{g}ecd,edf->{g}ecf", bufc, w_in.astype(dt))
    if w_gate is not None:
        h = a(jnp.einsum(f"{g}ecd,edf->{g}ecf", bufc, w_gate.astype(dt))) * h
    else:
        h = a(h)
    return jnp.einsum(f"{g}ecf,efd->{g}ecd", h, w_out.astype(dt))


def moe_apply(params: Dict, moe: MoEConfig, x: jnp.ndarray, act: str = "silu",
              glu: bool = True, policy: PrecisionPolicy = DEFAULT_POLICY
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss []).

    aux_loss is the standard load-balancing loss (mean_prob * frac_tokens
    per expert, scaled by E).
    """
    b, s, d = x.shape
    t, k, e = b * s, moe.top_k, moe.num_experts
    xf = x.reshape(t, d)

    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    gate, expert_ids = jax.lax.top_k(probs, k)                   # [T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss
    frac = jnp.mean(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    # ---- capacity-bounded scatter dispatch --------------------------------
    if t * k <= 4096:
        # decode / tiny batches: flat dropless dispatch
        cap = t * k
        flat_e = expert_ids.reshape(t * k)                       # token-major
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(t * k),
                                                     flat_e]
        keep = slot < cap
        x_rep = jnp.repeat(xf, k, axis=0)                        # [T*k, D]
        buf = jnp.zeros((e, cap, d), xf.dtype)
        buf = buf.at[flat_e, slot].add(
            x_rep * keep[:, None].astype(xf.dtype), mode="drop")
        out_buf = _expert_ffn(params, buf, act, glu, policy)     # [E, C, D]
        gathered = out_buf.at[flat_e, slot].get(mode="fill", fill_value=0)
        gathered = gathered * (gate.reshape(t * k, 1)
                               * keep[:, None]).astype(gathered.dtype)
        out = jnp.sum(gathered.reshape(t, k, d), axis=1)
    else:
        # group-wise dispatch (GShard-style): one capacity buffer PER BATCH
        # ROW, so the [B, E, C_g, D] buffer shards over (data, model) and
        # the expert einsum is fully local — a global [E, C, D] buffer
        # replicates expert compute across the data axis (16x waste,
        # observed on olmoe/moonshot baselines; §Perf iteration).
        sk = s * k
        cap = max(1, int(k * s * moe.capacity_factor / e))
        eids = expert_ids.reshape(b, sk)                         # [B, S*k]
        onehot = jax.nn.one_hot(eids, e, dtype=jnp.int32)        # [B, S*k, E]
        pos = jnp.cumsum(onehot, axis=1) - onehot
        slot = jnp.take_along_axis(pos, eids[..., None],
                                   axis=-1)[..., 0]              # [B, S*k]
        keep = slot < cap
        xg = x.astype(xf.dtype)                                  # [B, S, D]
        x_rep = jnp.repeat(xg, k, axis=1)                        # [B, S*k, D]
        b_idx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, sk))
        buf = jnp.zeros((b, e, cap, d), xf.dtype)
        buf = buf.at[b_idx, eids, slot].add(
            x_rep * keep[..., None].astype(xf.dtype), mode="drop")
        out_buf = _expert_ffn(params, buf, act, glu, policy)     # [B,E,C,D]
        gathered = out_buf.at[b_idx, eids, slot].get(mode="fill",
                                                     fill_value=0)
        w = (gate.reshape(b, sk, 1) * keep[..., None]).astype(gathered.dtype)
        out = jnp.sum((gathered * w).reshape(b, s, k, d), axis=2)
        out = out.reshape(t, d)

    if "shared" in params:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(params["shared"], xf, act, glu, policy)
    return out.reshape(b, s, d).astype(x.dtype), aux
