"""Batched serving loop: request queue -> bounded batching window ->
prefill -> greedy decode.

Straggler mitigation at serve time: the batching window is bounded (a
request waits at most ``window`` flushes), and batches are padded to a
fixed set of bucket sizes so every flush hits a pre-compiled program —
no compile stalls in the serving path.

Two granularities of progress:
  * ``flush()`` — blocking: serve one whole window (prefill + full decode).
  * ``step()``  — non-blocking building block: advance by ONE unit of work
    (a prefill or a single decode step) and return immediately.  This is
    what lets several servers — the router's accelerator pools — interleave
    on one host instead of each monopolizing it for a full generation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    output: Optional[np.ndarray] = None


@dataclass
class _ActiveWindow:
    """One in-progress bounded window (prefill done, decode underway)."""
    batch: List[Request]
    cache: object
    last: object                       # [b, 1] last sampled token
    gen: List[np.ndarray]
    remaining: int                     # decode steps left


class BatchingServer:
    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_batch: int = 8, prompt_len: int = 32,
                 max_len: int = 64):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._active: Optional[_ActiveWindow] = None
        self._prefill = jax.jit(
            lambda p, toks, cache: T.prefill(p, cfg, toks, cache, plan, tp))
        self._decode = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache, plan, tp))

    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-window)."""
        return len(self.queue) + (len(self._active.batch)
                                  if self._active else 0)

    def step(self) -> List[Request]:
        """Advance by one unit of work and return requests it completed.

        No active window: start one (prefill + first token) from the queue.
        Active window: run one decode step.  Returns [] until the window's
        last decode step, at which point the whole batch is finalized.
        """
        if self._active is None:
            if not self.queue:
                return []
            self._start_window()
        else:
            w = self._active
            out = self._decode(self.params, w.last.astype(jnp.int32), w.cache)
            w.cache = out.cache
            w.last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
            w.gen.append(np.asarray(w.last))
            w.remaining -= 1
        return self._finish_if_done()

    def flush(self) -> List[Request]:
        """Serve one bounded window to completion (blocking form of step)."""
        if self._active is None and not self.queue:
            return []
        while True:
            batch = self.step()
            if batch:
                return batch

    def _start_window(self) -> None:
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        b = self.max_batch                        # fixed bucket: no recompiles
        toks = np.zeros((b, self.prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len, self.tp)
        out = self._prefill(self.params, jnp.asarray(toks), cache)
        last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
        max_new = max(r.max_new for r in batch)
        self._active = _ActiveWindow(batch, out.cache, last,
                                     [np.asarray(last)], max_new - 1)

    def _finish_if_done(self) -> List[Request]:
        w = self._active
        if w is None or w.remaining > 0:
            return []
        gen = np.concatenate(w.gen, axis=1)       # [b, max_new]
        for i, r in enumerate(w.batch):
            r.output = gen[i, :r.max_new]
            self.done[r.rid] = r
        self._active = None
        return w.batch
