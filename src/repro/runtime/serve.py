"""Batched serving loop: request queue -> bounded batching window ->
prefill -> greedy decode.

Straggler mitigation at serve time: the batching window is bounded (a
request waits at most ``window`` flushes), and batches are padded to a
fixed set of bucket sizes so every flush hits a pre-compiled program —
no compile stalls in the serving path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    output: Optional[np.ndarray] = None


class BatchingServer:
    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_batch: int = 8, prompt_len: int = 32,
                 max_len: int = 64):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._prefill = jax.jit(
            lambda p, toks, cache: T.prefill(p, cfg, toks, cache, plan, tp))
        self._decode = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache, plan, tp))

    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        self.queue.append(req)

    def flush(self) -> List[Request]:
        """Serve up to max_batch queued requests (one bounded window)."""
        if not self.queue:
            return []
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        b = self.max_batch                        # fixed bucket: no recompiles
        toks = np.zeros((b, self.prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len, self.tp)
        out = self._prefill(self.params, jnp.asarray(toks), cache)
        cache = out.cache
        last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
        max_new = max(r.max_new for r in batch)
        gen = [np.asarray(last)]
        for _ in range(max_new - 1):
            out = self._decode(self.params, last.astype(jnp.int32), cache)
            cache = out.cache
            last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
            gen.append(np.asarray(last))
        gen = np.concatenate(gen, axis=1)         # [b, max_new]
        for i, r in enumerate(batch):
            r.output = gen[i, :r.max_new]
            self.done[r.rid] = r
        return batch
