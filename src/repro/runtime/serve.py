"""Serving loops: slot-based continuous batching (the fast path) and the
legacy windowed loop (kept as a measured baseline).

Two servers share one request API (``submit`` / ``step`` / ``flush`` /
``done``), so the serving facade's
:class:`~repro.serving.executor.EngineExecutor` drives either:

* :class:`ContinuousBatchingEngine` — a fixed set of *slots* over a
  shared paged KV pool (``runtime/paging.py``).  A request is admitted
  into any free slot the moment enough KV blocks exist (its whole
  ``prompt_len + max_new`` budget is reserved up front, so a running
  request can never strand mid-decode); it decodes for *exactly* its
  own ``max_new`` steps; the step it finishes, its blocks free and its
  slot is re-admittable — decode proceeds continuously while slots
  churn.  Admission that would overcommit the pool raises
  :class:`~repro.runtime.paging.OutOfBlocksError` internally; the
  request waits in the queue and the deferral is counted (the facade
  surfaces it as backpressure telemetry).  Attention runs the Pallas
  paged-decode kernel (``kernels/paged_attention.py``): the block table
  is walked in-kernel, so per-step HBM traffic is O(blocks touched),
  not O(batch * max_len) gather.  Sampling (greedy by default, or
  per-request temperature/top-k/seed via
  :class:`~repro.runtime.sampling.SamplingParams`) happens *inside* the
  fused decode program — one dispatch per step, ``[B]`` ints on the
  wire.

* :class:`WindowedBaselineServer` — the original *windowed* loop: a
  bounded window of requests prefills together, then every request
  decodes for ``max(max_new)`` steps.  Finished requests keep burning
  decode steps as padding, and newly-arrived requests wait for the
  whole window to drain.  Kept only as the baseline that
  ``benchmarks/decode_bench.py`` and ``benchmarks/router_bench.py``
  measure the continuous engine against.

``BatchingServer`` — the windowed loop's old public name — is now a
deprecated shim: it warns and forwards construction to the engine
(falling back to the windowed loop only for stacks paged decode cannot
serve).  New code should not call either constructor directly; build a
:class:`~repro.serving.FleetSpec` and serve through
:class:`~repro.serving.ServingClient` instead.

Shapes stay bucket-fixed in both servers (``max_batch`` / ``max_slots``
and ``prompt_len``), so every step hits a pre-compiled program — no
compile stalls in the serving path.

Two granularities of progress:
  * ``flush()`` — blocking: run until at least one request completes.
  * ``step()``  — non-blocking building block: advance by ONE unit of
    work and return immediately.  This is what lets several servers —
    the router's accelerator pools — interleave on one host instead of
    each monopolizing it for a full generation.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.runtime import paging
from repro.runtime.paging import BlockAllocator, OutOfBlocksError
from repro.runtime.sampling import GREEDY, SamplingParams, sample_logits


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    sampling: Optional[SamplingParams] = None   # None -> greedy
    output: Optional[np.ndarray] = None


@dataclass
class _ActiveWindow:
    """One in-progress bounded window (prefill done, decode underway)."""
    batch: List[Request]
    cache: object
    last: object                       # [b, 1] last sampled token
    gen: List[np.ndarray]
    remaining: int                     # decode steps left
    steps_done: int = 0                # decode steps taken so far


class WindowedBaselineServer:
    """The legacy windowed batching loop (greedy-only).  Baseline for the
    decode benchmarks; serve through ``repro.serving`` instead."""

    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_batch: int = 8, prompt_len: int = 32,
                 max_len: int = 64):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._active: Optional[_ActiveWindow] = None
        self._prefill = jax.jit(
            lambda p, toks, cache: T.prefill(p, cfg, toks, cache, plan, tp))
        self._decode = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache, plan, tp))
        self.reset_stats()

    def reset_stats(self) -> None:
        self.total_tokens = 0             # real sampled tokens only
        self.decode_steps = 0
        self.decode_tokens = 0            # tokens produced by decode steps
        self.decode_s = 0.0               # wall time inside decode steps
        self.deferrals = 0                # windowed loop never defers

    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        assert self.prompt_len + req.max_new <= self.max_len, \
            (req.rid, req.max_new, self.max_len)
        if req.sampling is not None and not req.sampling.greedy:
            warnings.warn(
                f"request {req.rid}: the windowed baseline decodes "
                f"greedily and ignores SamplingParams; use an engine-"
                f"backed pool for non-greedy sampling")
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-window)."""
        return len(self.queue) + (len(self._active.batch)
                                  if self._active else 0)

    @property
    def occupancy(self) -> float:
        """Fraction of batch slots doing useful work right now."""
        if self._active is None:
            return 0.0
        return len(self._active.batch) / self.max_batch

    def step(self) -> List[Request]:
        """Advance by one unit of work and return requests it completed.

        No active window: start one (prefill + first token) from the queue.
        Active window: run one decode step.  Returns [] until the window's
        last decode step, at which point the whole batch is finalized.
        """
        if self._active is None:
            if not self.queue:
                return []
            self._start_window()
        else:
            w = self._active
            t0 = time.perf_counter()
            out = self._decode(self.params, w.last.astype(jnp.int32), w.cache)
            w.cache = out.cache
            w.last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
            w.gen.append(np.asarray(w.last))
            self.decode_s += time.perf_counter() - t0
            w.remaining -= 1
            w.steps_done += 1
            self.decode_steps += 1
            # padding rows past a request's own max_new are not tokens
            useful = sum(1 for r in w.batch if w.steps_done <= r.max_new - 1)
            self.decode_tokens += useful
            self.total_tokens += useful
        return self._finish_if_done()

    def flush(self) -> List[Request]:
        """Serve one bounded window to completion (blocking form of step)."""
        if self._active is None and not self.queue:
            return []
        while True:
            batch = self.step()
            if batch:
                return batch

    def stats(self) -> Dict[str, float]:
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "decode_tokens": self.decode_tokens,
                "decode_s": self.decode_s,
                "deferrals": self.deferrals}

    def _start_window(self) -> None:
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        b = self.max_batch                        # fixed bucket: no recompiles
        toks = np.zeros((b, self.prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len, self.tp)
        out = self._prefill(self.params, jnp.asarray(toks), cache)
        last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
        max_new = max(r.max_new for r in batch)
        self.total_tokens += sum(1 for r in batch if r.max_new >= 1)
        self._active = _ActiveWindow(batch, out.cache, last,
                                     [np.asarray(last)], max_new - 1)

    def _finish_if_done(self) -> List[Request]:
        w = self._active
        if w is None or w.remaining > 0:
            return []
        gen = np.concatenate(w.gen, axis=1)       # [b, max_new]
        for i, r in enumerate(w.batch):
            r.output = gen[i, :r.max_new]
            self.done[r.rid] = r
        self._active = None
        return w.batch


def engine_or_windowed(params, cfg: ModelConfig,
                       plan: Optional[PartitionPlan] = None, tp: int = 1,
                       max_slots: int = 8, prompt_len: int = 32,
                       max_len: int = 64, block_size: int = 8,
                       num_blocks: Optional[int] = None,
                       on_fallback=None):
    """The one engine-with-windowed-fallback policy.

    Constructs a :class:`ContinuousBatchingEngine`; stacks paged decode
    cannot serve (hybrid/SSM mixers, sliding windows, int8 KV — the
    engine raises ``ValueError``) fall back to the windowed loop, after
    calling ``on_fallback(exc)`` if given.  Both the serving facade's
    ``make_server`` and the deprecated :func:`BatchingServer` shim come
    through here, so the fallback conditions live in exactly one place.
    """
    if max_len > prompt_len:
        try:
            return ContinuousBatchingEngine(
                params, cfg, plan=plan, tp=tp, max_slots=max_slots,
                prompt_len=prompt_len, max_len=max_len,
                block_size=block_size, num_blocks=num_blocks)
        except ValueError as e:    # non-pageable: keep the windowed loop
            if on_fallback is not None:
                on_fallback(e)
    return WindowedBaselineServer(params, cfg, plan=plan, tp=tp,
                                  max_batch=max_slots,
                                  prompt_len=prompt_len, max_len=max_len)


def BatchingServer(params, cfg: ModelConfig,
                   plan: Optional[PartitionPlan] = None, tp: int = 1,
                   max_batch: int = 8, prompt_len: int = 32,
                   max_len: int = 64):
    """Deprecated windowed-server entry point.

    Warns and forwards to :class:`ContinuousBatchingEngine` (same
    submit/step/flush/done API, strictly better scheduling), falling
    back to the windowed loop via :func:`engine_or_windowed`.  New code
    should build a :class:`repro.serving.FleetSpec` and go through
    :class:`repro.serving.ServingClient` instead.
    """
    warnings.warn(
        "BatchingServer is deprecated; serve through repro.serving "
        "(FleetSpec -> ServingClient). Forwarding to the continuous-"
        "batching engine.", DeprecationWarning, stacklevel=2)
    return engine_or_windowed(params, cfg, plan=plan, tp=tp,
                              max_slots=max_batch, prompt_len=prompt_len,
                              max_len=max_len)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
@dataclass
class _Slot:
    """One occupied decode slot."""
    req: Request
    gen: List[int]                     # sampled tokens so far
    remaining: int                     # decode steps left (exact)
    sampled: bool = False              # non-greedy sampling requested


class ContinuousBatchingEngine:
    """Slot-based continuous-batching decode over a paged KV pool.

    ``max_slots`` batch slots share a pool of ``num_blocks`` KV blocks
    of ``block_size`` tokens.  Requests admit into free slots as soon as
    the pool can cover their full ``prompt_len + max_new`` budget (the
    reservation is up-front, so admitted work never deadlocks on
    blocks), decode for exactly their own ``max_new`` steps, and free
    their slot + blocks the step they finish.  One ``step()`` =
    admissions (each a batch-1 prefill pasted into the pool) + one
    batched decode step for every occupied slot.

    Sampling is per-request (``Request.sampling``): greedy when unset,
    otherwise temperature/top-k with a counter-based key
    (``fold_in(seed, token_index)``) so outputs are independent of batch
    composition.  Both the admission prefill and the decode step sample
    inside their fused jitted programs.

    Per-token observability: set ``on_token`` to a callable
    ``(rid, token)``; it fires the step each token is sampled (admission
    first-tokens included) — this is what feeds the serving facade's
    ``ResponseHandle.stream()``.

    The engine keeps the block table and per-slot lengths as host-side
    numpy mirrors (the allocator is host code) and pushes them into the
    per-layer :class:`~repro.runtime.paging.PagedKVState` before each
    device call; device-side length bumps from ``append_tokens`` are
    mirrored by the host bookkeeping, so the push is idempotent.
    """

    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_slots: int = 8, prompt_len: int = 32,
                 max_len: int = 64, block_size: int = 8,
                 num_blocks: Optional[int] = None):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_slots, self.prompt_len = max_slots, prompt_len
        self.max_len, self.block_size = max_len, block_size
        assert max_len > prompt_len, (max_len, prompt_len)
        self.table_width = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = max_slots * self.table_width
        assert num_blocks >= self.table_width, \
            "pool smaller than one max-length request"
        self.alloc = BlockAllocator(num_blocks)
        self.table = -np.ones((max_slots, self.table_width), np.int32)
        self.lengths = np.zeros(max_slots, np.int32)
        self.caches = T.init_paged_decode_cache(
            cfg, max_slots, num_blocks, block_size, tp,
            max_blocks=self.table_width)
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.last = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._dirty = True                    # host table/lengths changed
        self.on_token: Optional[Callable[[int, int], None]] = None
        # per-slot sampling knobs, threaded through the jit boundary.
        # Device copies are refreshed per admission round (the only
        # place they change) and the per-token step counters only when
        # a sampled request is active, so the greedy decode hot path
        # pays no per-step transfers.
        self._temps = np.zeros(max_slots, np.float32)
        self._topks = np.zeros(max_slots, np.int32)
        self._seeds = np.zeros(max_slots, np.int32)
        self._gen_counts = np.zeros(max_slots, np.int32)  # tokens so far
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        self.reset_stats()
        # admissions prefill together at the max_slots bucket (rows for
        # non-admitted slots are dead weight but keep shapes fixed)
        self._prefill_cache = T.init_cache(cfg, max_slots, prompt_len, tp)
        # two compiled variants per program: a pure-argmax one (identical
        # to the pre-sampling program — an all-greedy batch pays zero
        # sampling overhead, which matters on tiny configs where the
        # PRNG work rivals the forward pass) and a sampling one; the
        # host picks per call based on the live slots
        self._admit_step = jax.jit(self._admit_impl, static_argnums=(8,))

        def _decode_greedy(p, toks, caches):
            out = T.decode_step(p, cfg, toks, caches, plan, tp)
            # greedy sampling inside the program: one dispatch per step,
            # [B] ints on the wire instead of [B, V] logits
            return jnp.argmax(out.logits[:, -1], axis=-1), out.cache
        self._decode = jax.jit(_decode_greedy)

        def _decode_sampled(p, toks, caches, temps, topks, seeds, steps):
            out = T.decode_step(p, cfg, toks, caches, plan, tp)
            nxt = sample_logits(out.logits[:, -1], temps, topks, seeds,
                                steps)
            return nxt, out.cache
        self._decode_with = jax.jit(_decode_sampled)

    def reset_stats(self) -> None:
        """Zero the telemetry counters (post-jit-warmup)."""
        self.total_tokens = 0                 # real sampled tokens only
        self.decode_steps = 0
        self.occupancy_sum = 0.0
        self.decode_tokens = 0                # tokens from decode steps only
        self.decode_s = 0.0                   # wall time in decode steps
        self.admit_s = 0.0                    # wall time in admission steps
        self.deferrals = 0                    # OutOfBlocks admission deferrals

    # ------------------------------------------------------------------
    # public API (shared with WindowedBaselineServer)
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        assert self.prompt_len + req.max_new <= self.max_len, \
            (req.rid, req.max_new, self.max_len)
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-slot)."""
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def occupancy(self) -> float:
        """Fraction of decode slots doing useful work right now."""
        return sum(s is not None for s in self.slots) / self.max_slots

    def step(self) -> List[Request]:
        """Admit into free slots, then run one decode step; returns the
        requests completed by either (admission completes ``max_new==1``
        requests outright — their single token comes from prefill)."""
        completed = self._admit()
        completed += self._decode_once()
        return completed

    def flush(self) -> List[Request]:
        """Blocking form: run until at least one request completes."""
        if not self.pending:
            return []
        while True:
            done = self.step()
            if done:
                return done

    def stats(self) -> Dict[str, float]:
        steps = max(self.decode_steps, 1)
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "mean_occupancy": self.occupancy_sum / steps,
                "decode_tokens": self.decode_tokens,
                "decode_s": self.decode_s,
                "admit_s": self.admit_s,
                "deferrals": self.deferrals}

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _admit_impl(self, params, toks, prefill_cache, caches, admit,
                    temps, topks, seeds, sampled):
        """One fused device call per admission round: bucket-shaped
        prefill, paste of every admitted row's KV into its paged blocks
        (non-admitted rows scatter to the trash row), and the first
        sampled token per row (token index 0 for the sampling key;
        ``sampled`` is static — all-greedy rounds compile to argmax)."""
        out = T.prefill(params, self.cfg, toks, prefill_cache,
                        self.plan, self.tp)
        new_caches = {}
        for key, st in caches.items():
            dc = out.cache[key]
            new_caches[key] = jax.vmap(
                paging.write_prefill_batch,
                in_axes=(0, 0, 0, None))(st, dc.k, dc.v, admit)
        logits = out.logits[:, -1]
        if sampled:
            firsts = sample_logits(logits, temps, topks, seeds,
                                   jnp.zeros_like(seeds))
        else:
            firsts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return firsts, new_caches

    def _push_tables(self) -> None:
        tbl = jnp.asarray(self.table)
        lens = jnp.asarray(self.lengths)

        def fix(st: paging.PagedKVState) -> paging.PagedKVState:
            return st._replace(
                block_table=jnp.broadcast_to(tbl, st.block_table.shape),
                lengths=jnp.broadcast_to(lens, st.lengths.shape))
        self.caches = jax.tree_util.tree_map(
            fix, self.caches,
            is_leaf=lambda s: isinstance(s, paging.PagedKVState))

    def _emit(self, rid: int, tok: int) -> None:
        if self.on_token is not None:
            self.on_token(rid, tok)

    def _admit(self) -> List[Request]:
        admits: List[tuple] = []
        for i in range(self.max_slots):
            if not self.queue:
                break
            if self.slots[i] is not None:
                continue
            req = self.queue[0]
            need = [int((self.table[j] >= 0).sum())
                    for j in range(self.max_slots)]
            need[i] = -(-(self.prompt_len + req.max_new) // self.block_size)
            try:
                self.table = paging.plan_blocks(self.table, self.alloc, need)
            except OutOfBlocksError:
                self.deferrals += 1    # defer admission; blocks will free
                break
            admits.append((i, self.queue.pop(0)))
        if not admits:
            return []
        self._push_tables()                # freed + freshly-planned rows
        self._dirty = False
        # every admission this round rides one fused prefill+paste call;
        # each admitted request occupies its slot's batch row, dead rows
        # keep the compiled shape fixed
        toks = np.zeros((self.max_slots, self.prompt_len), np.int32)
        admit = np.zeros(self.max_slots, bool)
        any_sampled = False
        for i, req in admits:
            toks[i, -req.prompt.shape[0]:] = req.prompt      # left-pad
            admit[i] = True
            sp = req.sampling or GREEDY
            self._temps[i] = sp.temperature
            self._topks[i] = sp.top_k
            self._seeds[i] = sp.seed
            any_sampled |= not sp.greedy
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        temps_d, topks_d, seeds_d = self._knobs_dev
        t0 = time.perf_counter()
        firsts, self.caches = self._admit_step(
            self.params, jnp.asarray(toks), self._prefill_cache,
            self.caches, jnp.asarray(admit), temps_d, topks_d, seeds_d,
            any_sampled)
        firsts = np.asarray(firsts)
        self.admit_s += time.perf_counter() - t0
        completed: List[Request] = []
        for i, req in admits:
            self.lengths[i] = self.prompt_len
            self._gen_counts[i] = 1
            tok = int(firsts[i])
            self.total_tokens += 1
            if req.max_new >= 1:
                self._emit(req.rid, tok)
            if req.max_new <= 1:       # done at admission (0 => empty,
                completed.append(       # matching the windowed baseline)
                    self._finalize(i, req, [tok][:req.max_new]))
            else:
                sp = req.sampling or GREEDY
                self.slots[i] = _Slot(req, [tok], req.max_new - 1,
                                      sampled=not sp.greedy)
                self.last[i, 0] = tok
        return completed

    def _decode_once(self) -> List[Request]:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        if self._dirty:
            self._push_tables()
            self._dirty = False
        any_sampled = any(s is not None and s.sampled for s in self.slots)
        t0 = time.perf_counter()
        if any_sampled:
            temps_d, topks_d, seeds_d = self._knobs_dev
            nxt, self.caches = self._decode_with(
                self.params, jnp.asarray(self.last), self.caches,
                temps_d, topks_d, seeds_d, jnp.asarray(self._gen_counts))
        else:
            nxt, self.caches = self._decode(
                self.params, jnp.asarray(self.last), self.caches)
        nxt = np.asarray(nxt)
        self.decode_s += time.perf_counter() - t0
        completed: List[Request] = []
        for i in active:
            self.lengths[i] += 1           # mirror device append_tokens
            self._gen_counts[i] += 1
            s = self.slots[i]
            tok = int(nxt[i])
            s.gen.append(tok)
            s.remaining -= 1
            self.last[i, 0] = nxt[i]
            self._emit(s.req.rid, tok)
            if s.remaining <= 0:
                completed.append(self._finalize(i, s.req, s.gen))
                self.slots[i] = None
        self.decode_steps += 1
        self.total_tokens += len(active)
        self.decode_tokens += len(active)
        self.occupancy_sum += len(active) / self.max_slots
        return completed

    def _finalize(self, i: int, req: Request, gen: List[int]) -> Request:
        req.output = np.asarray(gen, np.int32)
        self.done[req.rid] = req
        self.alloc.release(self.table[i][self.table[i] >= 0])
        self.table[i] = -1
        self.lengths[i] = 0
        self._dirty = True        # device sees the freed row at next push
        return req
