"""Serving loops: windowed batching (baseline) and slot-based continuous
batching (the fast path).

Two servers share one request API (``submit`` / ``step`` / ``flush`` /
``done``), so the router's :class:`~repro.router.pool.ServerExecutor`
drives either:

* :class:`BatchingServer` — the original *windowed* loop: a bounded
  window of requests prefills together, then every request decodes for
  ``max(max_new)`` steps.  Finished requests keep burning decode steps
  as padding, and newly-arrived requests wait for the whole window to
  drain.  Kept as the baseline that ``benchmarks/decode_bench.py``
  measures the continuous engine against.

* :class:`ContinuousBatchingEngine` — a fixed set of *slots* over a
  shared paged KV pool (``runtime/paging.py``).  A request is admitted
  into any free slot the moment enough KV blocks exist (its whole
  ``prompt_len + max_new`` budget is reserved up front, so a running
  request can never strand mid-decode); it decodes for *exactly* its
  own ``max_new`` steps; the step it finishes, its blocks free and its
  slot is re-admittable — decode proceeds continuously while slots
  churn.  Admission that would overcommit the pool raises
  :class:`~repro.runtime.paging.OutOfBlocksError` internally and the
  request simply waits in the queue.  Attention runs the Pallas
  paged-decode kernel (``kernels/paged_attention.py``): the block table
  is walked in-kernel, so per-step HBM traffic is O(blocks touched),
  not O(batch * max_len) gather.

Shapes stay bucket-fixed in both servers (``max_batch`` / ``max_slots``
and ``prompt_len``), so every step hits a pre-compiled program — no
compile stalls in the serving path.

Two granularities of progress:
  * ``flush()`` — blocking: run until at least one request completes.
  * ``step()``  — non-blocking building block: advance by ONE unit of
    work and return immediately.  This is what lets several servers —
    the router's accelerator pools — interleave on one host instead of
    each monopolizing it for a full generation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.runtime import paging
from repro.runtime.paging import BlockAllocator, OutOfBlocksError


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    output: Optional[np.ndarray] = None


@dataclass
class _ActiveWindow:
    """One in-progress bounded window (prefill done, decode underway)."""
    batch: List[Request]
    cache: object
    last: object                       # [b, 1] last sampled token
    gen: List[np.ndarray]
    remaining: int                     # decode steps left


class BatchingServer:
    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_batch: int = 8, prompt_len: int = 32,
                 max_len: int = 64):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._active: Optional[_ActiveWindow] = None
        self._prefill = jax.jit(
            lambda p, toks, cache: T.prefill(p, cfg, toks, cache, plan, tp))
        self._decode = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache, plan, tp))

    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-window)."""
        return len(self.queue) + (len(self._active.batch)
                                  if self._active else 0)

    @property
    def occupancy(self) -> float:
        """Fraction of batch slots doing useful work right now."""
        if self._active is None:
            return 0.0
        return len(self._active.batch) / self.max_batch

    def step(self) -> List[Request]:
        """Advance by one unit of work and return requests it completed.

        No active window: start one (prefill + first token) from the queue.
        Active window: run one decode step.  Returns [] until the window's
        last decode step, at which point the whole batch is finalized.
        """
        if self._active is None:
            if not self.queue:
                return []
            self._start_window()
        else:
            w = self._active
            out = self._decode(self.params, w.last.astype(jnp.int32), w.cache)
            w.cache = out.cache
            w.last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
            w.gen.append(np.asarray(w.last))
            w.remaining -= 1
        return self._finish_if_done()

    def flush(self) -> List[Request]:
        """Serve one bounded window to completion (blocking form of step)."""
        if self._active is None and not self.queue:
            return []
        while True:
            batch = self.step()
            if batch:
                return batch

    def _start_window(self) -> None:
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        b = self.max_batch                        # fixed bucket: no recompiles
        toks = np.zeros((b, self.prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len, self.tp)
        out = self._prefill(self.params, jnp.asarray(toks), cache)
        last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
        max_new = max(r.max_new for r in batch)
        self._active = _ActiveWindow(batch, out.cache, last,
                                     [np.asarray(last)], max_new - 1)

    def _finish_if_done(self) -> List[Request]:
        w = self._active
        if w is None or w.remaining > 0:
            return []
        gen = np.concatenate(w.gen, axis=1)       # [b, max_new]
        for i, r in enumerate(w.batch):
            r.output = gen[i, :r.max_new]
            self.done[r.rid] = r
        self._active = None
        return w.batch


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
@dataclass
class _Slot:
    """One occupied decode slot."""
    req: Request
    gen: List[int]                     # sampled tokens so far
    remaining: int                     # decode steps left (exact)


class ContinuousBatchingEngine:
    """Slot-based continuous-batching decode over a paged KV pool.

    ``max_slots`` batch slots share a pool of ``num_blocks`` KV blocks
    of ``block_size`` tokens.  Requests admit into free slots as soon as
    the pool can cover their full ``prompt_len + max_new`` budget (the
    reservation is up-front, so admitted work never deadlocks on
    blocks), decode for exactly their own ``max_new`` steps, and free
    their slot + blocks the step they finish.  One ``step()`` =
    admissions (each a batch-1 prefill pasted into the pool) + one
    batched decode step for every occupied slot.

    The engine keeps the block table and per-slot lengths as host-side
    numpy mirrors (the allocator is host code) and pushes them into the
    per-layer :class:`~repro.runtime.paging.PagedKVState` before each
    device call; device-side length bumps from ``append_tokens`` are
    mirrored by the host bookkeeping, so the push is idempotent.
    """

    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_slots: int = 8, prompt_len: int = 32,
                 max_len: int = 64, block_size: int = 8,
                 num_blocks: Optional[int] = None):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_slots, self.prompt_len = max_slots, prompt_len
        self.max_len, self.block_size = max_len, block_size
        assert max_len > prompt_len, (max_len, prompt_len)
        self.table_width = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = max_slots * self.table_width
        assert num_blocks >= self.table_width, \
            "pool smaller than one max-length request"
        self.alloc = BlockAllocator(num_blocks)
        self.table = -np.ones((max_slots, self.table_width), np.int32)
        self.lengths = np.zeros(max_slots, np.int32)
        self.caches = T.init_paged_decode_cache(
            cfg, max_slots, num_blocks, block_size, tp,
            max_blocks=self.table_width)
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.last = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._dirty = True                    # host table/lengths changed
        # telemetry
        self.total_tokens = 0                 # real sampled tokens only
        self.decode_steps = 0
        self.occupancy_sum = 0.0
        # admissions prefill together at the max_slots bucket (rows for
        # non-admitted slots are dead weight but keep shapes fixed)
        self._prefill_cache = T.init_cache(cfg, max_slots, prompt_len, tp)
        self._admit_step = jax.jit(self._admit_impl)

        def _decode_and_sample(p, toks, caches):
            out = T.decode_step(p, cfg, toks, caches, plan, tp)
            # greedy sampling inside the program: one dispatch per step,
            # [B] ints on the wire instead of [B, V] logits
            return jnp.argmax(out.logits[:, -1], axis=-1), out.cache
        self._decode = jax.jit(_decode_and_sample)

    # ------------------------------------------------------------------
    # public API (shared with BatchingServer)
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        assert self.prompt_len + req.max_new <= self.max_len, \
            (req.rid, req.max_new, self.max_len)
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-slot)."""
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def occupancy(self) -> float:
        """Fraction of decode slots doing useful work right now."""
        return sum(s is not None for s in self.slots) / self.max_slots

    def step(self) -> List[Request]:
        """Admit into free slots, then run one decode step; returns the
        requests completed by either (admission completes ``max_new==1``
        requests outright — their single token comes from prefill)."""
        completed = self._admit()
        completed += self._decode_once()
        return completed

    def flush(self) -> List[Request]:
        """Blocking form: run until at least one request completes."""
        if not self.pending:
            return []
        while True:
            done = self.step()
            if done:
                return done

    def stats(self) -> Dict[str, float]:
        steps = max(self.decode_steps, 1)
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "mean_occupancy": self.occupancy_sum / steps}

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _admit_impl(self, params, toks, prefill_cache, caches, admit):
        """One fused device call per admission round: bucket-shaped
        prefill, paste of every admitted row's KV into its paged blocks
        (non-admitted rows scatter to the trash row), and the first
        sampled token per row.  The intermediate dense prefill cache
        never leaves the XLA program."""
        out = T.prefill(params, self.cfg, toks, prefill_cache,
                        self.plan, self.tp)
        new_caches = {}
        for key, st in caches.items():
            dc = out.cache[key]
            new_caches[key] = jax.vmap(
                paging.write_prefill_batch,
                in_axes=(0, 0, 0, None))(st, dc.k, dc.v, admit)
        return jnp.argmax(out.logits[:, -1], axis=-1), new_caches

    def _push_tables(self) -> None:
        tbl = jnp.asarray(self.table)
        lens = jnp.asarray(self.lengths)

        def fix(st: paging.PagedKVState) -> paging.PagedKVState:
            return st._replace(
                block_table=jnp.broadcast_to(tbl, st.block_table.shape),
                lengths=jnp.broadcast_to(lens, st.lengths.shape))
        self.caches = jax.tree_util.tree_map(
            fix, self.caches,
            is_leaf=lambda s: isinstance(s, paging.PagedKVState))

    def _admit(self) -> List[Request]:
        admits: List[tuple] = []
        for i in range(self.max_slots):
            if not self.queue:
                break
            if self.slots[i] is not None:
                continue
            req = self.queue[0]
            need = [int((self.table[j] >= 0).sum())
                    for j in range(self.max_slots)]
            need[i] = -(-(self.prompt_len + req.max_new) // self.block_size)
            try:
                self.table = paging.plan_blocks(self.table, self.alloc, need)
            except OutOfBlocksError:
                break                      # defer admission; blocks will free
            admits.append((i, self.queue.pop(0)))
        if not admits:
            return []
        self._push_tables()                # freed + freshly-planned rows
        self._dirty = False
        # every admission this round rides one fused prefill+paste call;
        # each admitted request occupies its slot's batch row, dead rows
        # keep the compiled shape fixed
        toks = np.zeros((self.max_slots, self.prompt_len), np.int32)
        admit = np.zeros(self.max_slots, bool)
        for i, req in admits:
            toks[i, -req.prompt.shape[0]:] = req.prompt      # left-pad
            admit[i] = True
        firsts, self.caches = self._admit_step(
            self.params, jnp.asarray(toks), self._prefill_cache,
            self.caches, jnp.asarray(admit))
        firsts = np.asarray(firsts)
        completed: List[Request] = []
        for i, req in admits:
            self.lengths[i] = self.prompt_len
            tok = int(firsts[i])
            self.total_tokens += 1
            if req.max_new <= 1:       # done at admission (0 => empty,
                completed.append(       # matching the windowed baseline)
                    self._finalize(i, req, [tok][:req.max_new]))
            else:
                self.slots[i] = _Slot(req, [tok], req.max_new - 1)
                self.last[i, 0] = tok
        return completed

    def _decode_once(self) -> List[Request]:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        if self._dirty:
            self._push_tables()
            self._dirty = False
        nxt, self.caches = self._decode(self.params, jnp.asarray(self.last),
                                        self.caches)
        nxt = np.asarray(nxt)
        completed: List[Request] = []
        for i in active:
            self.lengths[i] += 1           # mirror device append_tokens
            s = self.slots[i]
            s.gen.append(int(nxt[i]))
            s.remaining -= 1
            self.last[i, 0] = nxt[i]
            if s.remaining <= 0:
                completed.append(self._finalize(i, s.req, s.gen))
                self.slots[i] = None
        self.decode_steps += 1
        self.total_tokens += len(active)
        self.occupancy_sum += len(active) / self.max_slots
        return completed

    def _finalize(self, i: int, req: Request, gen: List[int]) -> Request:
        req.output = np.asarray(gen, np.int32)
        self.done[req.rid] = req
        self.alloc.release(self.table[i][self.table[i] >= 0])
        self.table[i] = -1
        self.lengths[i] = 0
        self._dirty = True        # device sees the freed row at next push
        return req
